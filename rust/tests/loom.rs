//! Loom model-checking suite for the parallel engine's sync protocols.
//!
//! Runs only under `RUSTFLAGS="--cfg loom"` (the dedicated CI lane):
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom
//! ```
//!
//! Under that cfg the crate's `parallel::sync` shim swaps `std::sync` for
//! loom's instrumented types, and each `loom::model` block below explores
//! *every* interleaving of its threads (bounded by preemptions where
//! noted). Assertion style: the shared payloads are loom `UnsafeCell`s —
//! plain non-atomic data — so any access not ordered by the protocol under
//! test is reported as a concurrency bug by the model itself, not merely a
//! flaky assertion. These tests therefore *prove* the happens-before
//! claims that the `// SAFETY:` comments in `parallel/` appeal to.

#![cfg(loom)]

use kaczmarz::parallel::{ShutdownSignal, SpinBarrier, WorkerPool};
use kaczmarz::serve::SolveControl;
use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

/// A plain, non-atomic payload. Loom's instrumented `UnsafeCell` turns any
/// unsynchronized concurrent access into a model failure, which is exactly
/// the probe we want: reading it after a protocol step *proves* the step
/// established happens-before.
struct Payload(UnsafeCell<usize>);

// SAFETY: the access discipline is the subject under test — loom itself
// rejects any execution in which two threads touch the cell without an
// ordering edge, so a `Sync` assertion here cannot hide a real race.
unsafe impl Sync for Payload {}

impl Payload {
    fn new(v: usize) -> Self {
        Payload(UnsafeCell::new(v))
    }

    fn read(&self) -> usize {
        // SAFETY: loom validates that this shared read is ordered against
        // every write (any violation fails the model).
        self.0.with(|p| unsafe { *p })
    }

    fn write(&self, v: usize) {
        // SAFETY: loom validates that this write is ordered against every
        // other access (any violation fails the model).
        self.0.with_mut(|p| unsafe { *p = v });
    }

    fn bump(&self) {
        // SAFETY: as in `write`.
        self.0.with_mut(|p| unsafe { *p += 1 });
    }
}

/// The core barrier claim every solver's SAFETY comments rely on: a plain
/// write made *before* a crossing is visible (and race-free) to every
/// thread *after* the crossing.
#[test]
fn spin_barrier_establishes_happens_before() {
    loom::model(|| {
        let barrier = Arc::new(SpinBarrier::new(2));
        let cell = Arc::new(Payload::new(0));
        let (b2, c2) = (Arc::clone(&barrier), Arc::clone(&cell));
        let writer = thread::spawn(move || {
            c2.write(42);
            b2.wait();
        });
        barrier.wait();
        assert_eq!(cell.read(), 42);
        writer.join().unwrap();
    });
}

/// Reuse across generations — the solvers cross one barrier hundreds of
/// times per solve. The count-reset-before-generation-flip order in
/// `SpinBarrier::wait` is what makes generation `g+1` safe to enter while
/// stragglers from `g` are still leaving; a regression here shows up as a
/// lost wakeup (model deadlock) or a payload race.
#[test]
fn spin_barrier_reuse_across_generations() {
    loom::model(|| {
        let barrier = Arc::new(SpinBarrier::new(2));
        let cell = Arc::new(Payload::new(0));
        let (b2, c2) = (Arc::clone(&barrier), Arc::clone(&cell));
        let t = thread::spawn(move || {
            c2.write(1);
            b2.wait(); // generation 0 -> 1: publish the write above
            b2.wait(); // generation 1 -> 2: wait out the peer's write phase
            assert_eq!(c2.read(), 2);
        });
        barrier.wait();
        assert_eq!(cell.read(), 1);
        cell.write(2);
        barrier.wait();
        t.join().unwrap();
    });
}

/// The lifetime-erasure contract of `WorkerPool::run` (module docs steps
/// 1-3): `run` returns only after every participant's call through the
/// erased job pointer has completed. The accesses after `run` would race
/// with any worker still writing inside the job — loom would fail the
/// model — so passing proves there is no use-after-return window.
#[test]
fn pool_run_returns_only_after_every_participant() {
    loom::model(|| {
        let pool = WorkerPool::new();
        let slots = [Payload::new(0), Payload::new(0)];
        pool.run(2, |t| {
            slots[t].bump();
        });
        for s in &slots {
            assert_eq!(s.read(), 1);
        }
        // Joins the parked worker; loom requires every thread to finish.
        drop(pool);
    });
}

/// The oversubscription path (protocol step 2): a resident worker with
/// `t >= q` must record the new epoch and park again without touching the
/// job pointer. The counter is deliberately Relaxed — the pool's own
/// mutex handshake, not the counter's ordering, is what makes the final
/// reads exact.
#[test]
fn pool_worker_skips_epochs_it_does_not_participate_in() {
    let mut builder = loom::model::Builder::new();
    // Three threads across two condvar-parked epochs: bound preemptions to
    // keep the state space tractable; the protocol-relevant interleavings
    // (skip vs join ordering) all occur within the bound.
    builder.preemption_bound = Some(2);
    builder.check(|| {
        let pool = WorkerPool::new();
        let hits = AtomicUsize::new(0);
        pool.run(3, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
        // Worker t = 2 stays resident but is not a participant of this
        // q = 2 epoch; if it joined anyway the count would reach 5 + 1.
        pool.run(2, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        drop(pool);
    });
}

/// AsyRK shutdown exactness: once the monitor observes `live == 0`
/// (Acquire, pairing with the worker's Release `worker_exit`), every
/// Relaxed `record_update` the worker made is visible — the final count
/// is exact, not approximate. Downgrading `worker_exit` to Relaxed makes
/// loom find an execution where the assertion reads a stale count.
#[test]
fn shutdown_signal_publishes_exact_update_count() {
    loom::model(|| {
        let sig = Arc::new(ShutdownSignal::new(1));
        let s2 = Arc::clone(&sig);
        let worker = thread::spawn(move || {
            s2.record_update();
            s2.record_update();
            s2.worker_exit();
        });
        while sig.live_workers() != 0 {
            thread::yield_now();
        }
        assert_eq!(sig.updates(), 2);
        worker.join().unwrap();
    });
}

/// The `stop` flag's Release/Acquire pair (the PR's ordering fix: the
/// previous SeqCst-store/Relaxed-load mix established no happens-before
/// edge at all). A worker that observes `should_stop()` must also see
/// everything the monitor wrote before `request_stop()` — checked through
/// a deliberately Relaxed side payload, so only the stop pair itself can
/// provide the edge.
#[test]
fn stop_release_pairs_with_worker_acquire() {
    loom::model(|| {
        let sig = Arc::new(ShutdownSignal::new(1));
        let flag = Arc::new(AtomicUsize::new(0));
        let (s2, f2) = (Arc::clone(&sig), Arc::clone(&flag));
        let worker = thread::spawn(move || {
            while !s2.should_stop() {
                thread::yield_now();
            }
            assert_eq!(f2.load(Ordering::Relaxed), 7);
            s2.worker_exit();
        });
        flag.store(7, Ordering::Relaxed);
        sig.request_stop();
        worker.join().unwrap();
    });
}

/// The serving cancel token's Release/Acquire pair: a checkpoint that
/// observes the halt must also see everything the canceller wrote before
/// `cancel()` — probed through a plain payload, so only the cancel flag's
/// ordering can provide the edge. This is the happens-before the admission
/// lanes rely on when they read job state after a cancelled solve returns.
#[test]
fn solve_control_cancel_publishes_prior_writes() {
    loom::model(|| {
        let control = SolveControl::new();
        let cell = Arc::new(Payload::new(0));
        let (c2, p2) = (control.clone(), Arc::clone(&cell));
        let canceller = thread::spawn(move || {
            p2.write(9);
            c2.cancel();
        });
        // Poll like a StopCheck checkpoint. Observing the halt must imply
        // visibility of the pre-cancel write.
        if control.poll().is_some() {
            assert_eq!(cell.read(), 9);
        }
        canceller.join().unwrap();
        // After the join the cancel is certainly visible and recorded.
        let halt = control.poll().expect("cancel must be observed");
        assert_eq!(control.halted(), Some(halt));
    });
}

/// First-recorded-reason-wins: when two pollers race to record a halt, the
/// compare-exchange in `SolveControl::record` guarantees every observer —
/// including the losing poller's own return value — agrees on one winner.
#[test]
fn solve_control_halt_reason_is_agreed_by_racing_pollers() {
    loom::model(|| {
        let control = SolveControl::new();
        let c2 = control.clone();
        let peer = thread::spawn(move || {
            c2.cancel();
            c2.poll()
        });
        let mine = control.poll();
        let theirs = peer.join().unwrap();
        let winner = control.halted();
        assert!(winner.is_some(), "the peer's cancel must be recorded");
        assert_eq!(theirs, winner, "poller and record must agree");
        if mine.is_some() {
            assert_eq!(mine, winner, "racing poller must see the same winner");
        }
    });
}
