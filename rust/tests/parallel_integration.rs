//! Integration of the shared-memory engine: parallel solvers vs their
//! sequential semantic references, across strategies, schemes, and thread
//! counts (including oversubscription).

use kaczmarz::data::DatasetBuilder;
use kaczmarz::parallel::{
    AsyRkSolver, AveragingStrategy, BlockSequentialRk, ParallelRka, ParallelRkab,
};
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::sampling::SamplingScheme;
use kaczmarz::solvers::{SolveOptions, Solver};

#[test]
fn rka_all_strategies_all_thread_counts() {
    let sys = DatasetBuilder::new(400, 16).seed(1).consistent();
    let opts = SolveOptions::default();
    for q in [1usize, 2, 4, 8] {
        for strategy in [
            AveragingStrategy::Critical,
            AveragingStrategy::Atomic,
            AveragingStrategy::Reduce,
            AveragingStrategy::MatrixGather,
        ] {
            let r = ParallelRka::new(3, q, 1.0).with_strategy(strategy).solve(&sys, &opts);
            assert!(r.converged, "q={q} {strategy:?}");
            assert!(sys.error_sq(&r.x) < 1e-8, "q={q} {strategy:?}");
        }
    }
}

#[test]
fn rka_iteration_counts_match_sequential_reference() {
    // Same seeds => identical row streams => identical iteration counts
    // (modulo FP reassociation affecting the last iteration, so allow 1%).
    let sys = DatasetBuilder::new(500, 20).seed(2).consistent();
    let opts = SolveOptions::default();
    for q in [2usize, 4] {
        let par = ParallelRka::new(11, q, 1.0).solve(&sys, &opts).iterations;
        let seq = RkaSolver::new(11, q, 1.0).solve(&sys, &opts).iterations;
        let diff = (par as f64 - seq as f64).abs() / seq as f64;
        assert!(diff < 0.01, "q={q}: par {par} vs seq {seq}");
    }
}

#[test]
fn rkab_matches_sequential_across_block_sizes() {
    let sys = DatasetBuilder::new(400, 16).seed(3).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(30);
    for bs in [1usize, 4, 16, 64] {
        let par = ParallelRkab::new(7, 4, bs, 1.0).solve(&sys, &opts);
        let seq = RkabSolver::new(7, 4, bs, 1.0).solve(&sys, &opts);
        let drift: f64 =
            par.x.iter().zip(&seq.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let scale = seq.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "bs={bs} drift {drift}");
    }
}

#[test]
fn rkab_partitioned_equals_distributed_sampling_semantics() {
    let sys = DatasetBuilder::new(400, 16).seed(4).consistent();
    let opts = SolveOptions::default();
    let r = ParallelRkab::new(5, 4, 16, 1.0)
        .with_scheme(SamplingScheme::Partitioned)
        .solve(&sys, &opts);
    assert!(r.converged);
}

#[test]
fn block_sequential_same_chain_as_rk() {
    let sys = DatasetBuilder::new(300, 64).seed(5).consistent();
    let opts = SolveOptions::default();
    let counts: Vec<usize> = [1usize, 2, 4]
        .iter()
        .map(|&q| BlockSequentialRk::new(13, q).solve(&sys, &opts).iterations)
        .collect();
    // The chain is identical regardless of thread count.
    assert_eq!(counts[0], counts[1]);
    assert_eq!(counts[1], counts[2]);
}

#[test]
fn asyrk_error_floor_grows_with_threads_on_dense() {
    // The §2.3.3 point: HOGWILD assumptions break on dense systems — more
    // threads means more overwritten updates. We check it still converges
    // for small q but takes more updates than sequential RK-equivalent.
    let sys = DatasetBuilder::new(300, 12).seed(6).consistent();
    let opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(3_000_000);
    let r1 = AsyRkSolver::new(3, 1).solve(&sys, &opts);
    let r4 = AsyRkSolver::new(3, 4).solve(&sys, &opts);
    assert!(r1.converged && r4.converged);
    // Stale-read updates waste work: q=4 should use at least as many total
    // row updates as q=1 (allow small slack for run-to-run noise).
    assert!(
        r4.iterations as f64 > 0.8 * r1.iterations as f64,
        "q4 {} vs q1 {}",
        r4.iterations,
        r1.iterations
    );
}

#[test]
fn pooled_solves_are_bit_deterministic_and_leak_free() {
    // Two consecutive solves on the same (global) worker pool must produce
    // bit-identical iterates — any state leaking between dispatches (stale
    // job, reused buffer, sampler carry-over) would show up here. For RKAB
    // the deterministic gather additionally pins the parallel result to the
    // sequential reference exactly.
    let sys = DatasetBuilder::new(300, 16).seed(31).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(40);

    let seq = RkabSolver::new(5, 4, 8, 1.0).solve(&sys, &opts);
    let first = ParallelRkab::new(5, 4, 8, 1.0).solve(&sys, &opts);
    let second = ParallelRkab::new(5, 4, 8, 1.0).solve(&sys, &opts);
    for ((a, b), s) in first.x.iter().zip(&second.x).zip(&seq.x) {
        assert_eq!(a.to_bits(), b.to_bits(), "pooled RKAB solves differ between dispatches");
        assert_eq!(a.to_bits(), s.to_bits(), "pooled RKAB differs from sequential reference");
    }

    // RKA through the deterministic (Fig. 3) gather: repeatable bit-for-bit
    // across two dispatches on the same pool.
    let opts = SolveOptions::default().with_fixed_iterations(150);
    let first = ParallelRka::new(5, 4, 1.0)
        .with_strategy(AveragingStrategy::MatrixGather)
        .solve(&sys, &opts);
    let second = ParallelRka::new(5, 4, 1.0)
        .with_strategy(AveragingStrategy::MatrixGather)
        .solve(&sys, &opts);
    for (a, b) in first.x.iter().zip(&second.x) {
        assert_eq!(a.to_bits(), b.to_bits(), "pooled RKA solves differ between dispatches");
    }
}

#[test]
fn pool_spawns_nothing_after_warmup() {
    // The point of the persistent engine: repeated solves reuse the parked
    // workers. A dedicated pool (immune to other tests growing the global
    // one concurrently) must spawn exactly q - 1 workers on the first solve
    // and zero afterwards.
    use kaczmarz::parallel::WorkerPool;
    use std::sync::Arc;
    let pool = Arc::new(WorkerPool::new());
    let sys = DatasetBuilder::new(200, 10).seed(33).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(20);
    let q = 4;
    assert_eq!(pool.worker_count(), 0);
    ParallelRkab::new(1, q, 4, 1.0).with_pool(Arc::clone(&pool)).solve(&sys, &opts);
    assert_eq!(pool.worker_count(), q - 1, "first solve spawns the workers");
    for seed in 0..10 {
        ParallelRkab::new(seed, q, 4, 1.0).with_pool(Arc::clone(&pool)).solve(&sys, &opts);
        ParallelRka::new(seed, q, 1.0).with_pool(Arc::clone(&pool)).solve(&sys, &opts);
    }
    assert_eq!(pool.worker_count(), q - 1, "solves at warm q must not spawn workers");
}

#[test]
fn oversubscribed_thread_counts_still_correct() {
    // The paper runs 64 threads; this container has fewer cores. The engine
    // must stay correct under oversubscription.
    let sys = DatasetBuilder::new(300, 12).seed(7).consistent();
    let opts = SolveOptions::default().with_max_iterations(2_000_000);
    let q = 2 * std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let r = ParallelRka::new(3, q, 1.0).solve(&sys, &opts);
    assert!(r.converged, "q={q}");
    let r = ParallelRkab::new(3, q, 12, 1.0).solve(&sys, &opts);
    assert!(r.converged, "rkab q={q}");
}
