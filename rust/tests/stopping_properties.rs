//! Property tests for the stopping-criterion contract introduced with
//! `StoppingCriterion`:
//!
//! 1. on consistent systems, `Residual` and `ReferenceError` stopping agree
//!    on convergence for every solver layer (sequential, shared-memory,
//!    asynchronous, distributed);
//! 2. on an inconsistent system, `Residual` stopping reports `converged`
//!    **iff** the tolerance is achievable, i.e. at or above the
//!    least-squares floor `‖A x_LS - b‖²` computed by CGLS — below the
//!    floor, no iterate of any solver can ever satisfy it;
//! 3. fixed-iteration runs never evaluate the initial error (it is lazy):
//!    a system carrying **no reference solution at all** — where any
//!    consult panics — solves cleanly under a fixed budget in every layer,
//!    which pins the evaluation count to exactly zero. The same laziness is
//!    what lets reference-free `SolveQueue` jobs run **in place, zero
//!    clones** (asserted below via rhs-buffer pointer identity).

use kaczmarz::batch::{BatchJob, BatchSolver, SolveQueue};
use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::distributed::{DistRka, DistRkab, Placement, SimCluster};
use kaczmarz::linalg::gemv;
use kaczmarz::metrics::History;
use kaczmarz::parallel::{AsyRkSolver, BlockSequentialRk, ParallelRka, ParallelRkab};
use kaczmarz::solvers::cgls::solve_least_squares;
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, SolveResult, Solver};
use std::sync::atomic::{AtomicBool, Ordering};

/// Absolute squared-residual tolerance used by the consistent-system
/// properties (a ~12-order reduction from the initial `‖b‖²` of these
/// systems — comfortably inside f64 and reached in a few hundred to a few
/// thousand iterations by every solver here).
const RESID_TOL_SQ: f64 = 1e-6;

fn residual_sq(sys: &LinearSystem, x: &[f64]) -> f64 {
    let r = sys.residual_norm(x);
    r * r
}

/// The same system, stripped of every reference solution: any call to
/// `error_sq` panics, so a run that completes proves zero consultations.
fn strip_reference(sys: &LinearSystem) -> LinearSystem {
    LinearSystem::new(sys.a.clone(), sys.b.clone(), None, true)
}

/// Every `Solver`-trait implementation in the crate, smallest viable
/// parallelism degrees (the container may have few cores; the pool
/// tolerates oversubscription).
fn all_trait_solvers(seed: u32) -> Vec<(&'static str, Box<dyn Solver>)> {
    vec![
        ("CK", Box::new(CkSolver::new())),
        ("RK", Box::new(RkSolver::new(seed))),
        ("RKA", Box::new(RkaSolver::new(seed, 4, 1.0))),
        ("RKAB", Box::new(RkabSolver::new(seed, 4, 8, 1.0))),
        ("RKA-parallel", Box::new(ParallelRka::new(seed, 3, 1.0))),
        ("RKAB-parallel", Box::new(ParallelRkab::new(seed, 3, 8, 1.0))),
        ("RK-block-seq", Box::new(BlockSequentialRk::new(seed, 2))),
        ("AsyRK", Box::new(AsyRkSolver::new(seed, 2))),
    ]
}

// ---------------------------------------------------------------------------
// Property 1: criterion agreement on consistent systems.
// ---------------------------------------------------------------------------

#[test]
fn consistent_criteria_agree_for_every_trait_solver() {
    let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
    let by_error = SolveOptions::default();
    let by_residual = SolveOptions::default().with_residual_stopping(RESID_TOL_SQ, 8);
    for (name, s) in all_trait_solvers(3) {
        // AsyRK's racy dense updates converge more slowly (that is the
        // paper's point about it); give it looser — still 12-orders-deep —
        // targets under both criteria so the test stays fast.
        let (by_error, by_residual, tol_sq) = if name == "AsyRK" {
            (
                SolveOptions::default().with_tolerance(1e-6),
                SolveOptions::default().with_residual_stopping(1e-3, 1),
                1e-3,
            )
        } else {
            (by_error.clone(), by_residual.clone(), RESID_TOL_SQ)
        };
        let e = s.solve(&sys, &by_error);
        assert!(e.converged && !e.diverged, "{name}: reference-error run did not converge");
        let r = s.solve(&sys, &by_residual);
        assert!(r.converged && !r.diverged, "{name}: residual run did not converge");
        // The quality certificate: the returned iterate really satisfies
        // the residual bound the criterion stopped on. AsyRK's workers can
        // land a few more racy updates between the monitor's passing check
        // and the stop flag, so it gets slack on the *final* iterate; the
        // synchronous solvers stop exactly at the certified checkpoint.
        let slack = if name == "AsyRK" { 16.0 } else { 1.0 };
        assert!(
            residual_sq(&sys, &r.x) < slack * tol_sq,
            "{name}: converged=true but residual² = {:.3e}",
            residual_sq(&sys, &r.x)
        );
    }
}

#[test]
fn consistent_criteria_agree_for_distributed_solvers() {
    let sys = DatasetBuilder::new(240, 10).seed(2).consistent();
    let cluster = SimCluster::new(3, Placement::two_per_node());
    let by_error = SolveOptions::default();
    let by_residual = SolveOptions::default().with_residual_stopping(RESID_TOL_SQ, 8);

    let e = DistRka::new(3, 1.0).solve(&sys, &by_error, &cluster);
    let r = DistRka::new(3, 1.0).solve(&sys, &by_residual, &cluster);
    assert!(e.converged, "DistRka reference-error run did not converge");
    assert!(r.converged, "DistRka residual run did not converge");
    assert!(residual_sq(&sys, &r.x) < RESID_TOL_SQ);

    let e = DistRkab::new(3, 8, 1.0).solve(&sys, &by_error, &cluster);
    let r = DistRkab::new(3, 8, 1.0).solve(&sys, &by_residual, &cluster);
    assert!(e.converged, "DistRkab reference-error run did not converge");
    assert!(r.converged, "DistRkab residual run did not converge");
    assert!(residual_sq(&sys, &r.x) < RESID_TOL_SQ);
}

// ---------------------------------------------------------------------------
// Property 2: achievability on inconsistent systems (the CGLS floor).
// ---------------------------------------------------------------------------

#[test]
fn inconsistent_residual_stopping_converges_iff_tolerance_is_achievable() {
    let sys = DatasetBuilder::new(300, 8).seed(33).inconsistent();
    let x_ls = solve_least_squares(&sys, 1e-12, 20_000).unwrap();
    let floor_sq = residual_sq(&sys, &x_ls);
    assert!(floor_sq > 0.0, "inconsistent by construction");

    // Self-calibration: where does RKA(q=16) actually plateau? (Fixed runs
    // need no reference and evaluate no metric, so this measures only the
    // iterate trajectory.) The plateau can never undercut the LS floor.
    let plateau = RkaSolver::new(5, 16, 1.0)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(10_000));
    let plateau_sq = residual_sq(&sys, &plateau.x);
    assert!(
        plateau_sq >= floor_sq * (1.0 - 1e-9),
        "plateau {plateau_sq:.6e} below the CGLS floor {floor_sq:.6e}?!"
    );

    // Achievable: 4x the measured plateau (and therefore >= the floor).
    // The same seed retraces the same iterate path, so a checkpoint under
    // the tolerance is guaranteed well within the calibration horizon.
    let achievable = 4.0 * plateau_sq;
    let r = RkaSolver::new(5, 16, 1.0).solve(
        &sys,
        &SolveOptions::default()
            .with_residual_stopping(achievable, 16)
            .with_max_iterations(100_000),
    );
    assert!(r.converged, "achievable tolerance {achievable:.3e} not reached");
    assert!(residual_sq(&sys, &r.x) < achievable);

    // Unachievable: below the least-squares floor no iterate of any solver
    // can ever satisfy the test — must exhaust the budget unconverged.
    let impossible = 0.5 * floor_sq;
    let r = RkaSolver::new(5, 16, 1.0).solve(
        &sys,
        &SolveOptions::default()
            .with_residual_stopping(impossible, 8)
            .with_max_iterations(4_000),
    );
    assert!(!r.converged, "converged below the LS floor — impossible");
    assert!(!r.diverged);
    assert_eq!(r.iterations, 4_000, "must run out the full budget");
}

// ---------------------------------------------------------------------------
// Property 3: fixed-iteration runs never compute the initial error.
// ---------------------------------------------------------------------------

#[test]
fn fixed_budget_runs_never_touch_the_reference() {
    // The probe: a system with NO reference solution. `error_sq` panics on
    // it, so a clean pass pins the reference-evaluation count of every
    // solver layer at exactly zero.
    let sys = strip_reference(&DatasetBuilder::new(150, 8).seed(5).consistent());
    let opts = SolveOptions::default().with_fixed_iterations(40);
    for (name, s) in all_trait_solvers(3) {
        let r = s.solve(&sys, &opts);
        // Nothing was measured, so nothing can claim convergence.
        assert!(!r.converged, "{name}: fixed-budget run claimed convergence");
        assert!(r.iterations >= 40, "{name}: budget not spent");
    }
    let cluster = SimCluster::new(2, Placement::two_per_node());
    let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
    assert!(!r.converged && r.iterations == 40);
    let r = DistRkab::new(3, 4, 1.0).solve(&sys, &opts, &cluster);
    assert!(!r.converged && r.iterations == 40);
}

#[test]
fn fixed_budget_runs_report_not_converged_even_with_a_reference() {
    // The converged-semantics fix is about meaning, not about a missing
    // reference: even when x* is known, a fixed budget measures nothing.
    let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(60);
    for (name, s) in all_trait_solvers(7) {
        let r = s.solve(&sys, &opts);
        assert!(!r.converged, "{name}: fixed-budget run claimed convergence");
    }
}

// ---------------------------------------------------------------------------
// The serving consequences: reference-free batch/queue jobs.
// ---------------------------------------------------------------------------

#[test]
fn residual_stopped_queue_jobs_converge_without_reference() {
    // Before this contract, a reference-free job was rejected under any
    // tolerance stopping; now the residual criterion certifies quality.
    let system = strip_reference(&DatasetBuilder::new(200, 8).seed(7).consistent());
    let mut queue = SolveQueue::new();
    queue.push(system, SolveOptions::default().with_residual_stopping(1e-6, 32));
    let reports = queue.run(&RkSolver::new(3)).unwrap();
    assert!(reports[0].result.converged, "residual stopping must certify the solve");
    assert!(reports[0].residual_norm * reports[0].residual_norm < 1e-6);
}

/// A `Solver` that records whether the system it is handed lives at the
/// exact rhs buffer it expects — i.e. whether the queue solved the job's
/// own system rather than any clone (a clone would re-heap `b`).
struct InPlaceProbe {
    expected_b: usize,
    saw_in_place: AtomicBool,
}

impl Solver for InPlaceProbe {
    fn name(&self) -> &'static str {
        "in-place-probe"
    }
    fn solve(&self, system: &LinearSystem, _opts: &SolveOptions) -> SolveResult {
        if system.b.as_ptr() as usize == self.expected_b {
            self.saw_in_place.store(true, Ordering::Relaxed);
        }
        SolveResult {
            x: vec![0.0; system.cols()],
            iterations: 0,
            converged: false,
            diverged: false,
            seconds: 0.0,
            rows_used: 0,
            history: History::default(),
        }
    }
}

#[test]
fn reference_free_queue_jobs_run_in_place_zero_clones() {
    let system = strip_reference(&DatasetBuilder::new(120, 6).seed(8).consistent());
    // A Vec's heap buffer is stable across moves: pin the rhs address now,
    // before the queue takes ownership.
    let probe = InPlaceProbe {
        expected_b: system.b.as_ptr() as usize,
        saw_in_place: AtomicBool::new(false),
    };
    let mut queue = SolveQueue::new();
    queue.push(system, SolveOptions::default().with_residual_stopping(1e-6, 16));
    queue.run(&probe).unwrap();
    assert!(
        probe.saw_in_place.load(Ordering::Relaxed),
        "queue must hand the solver the job's own system, not a clone"
    );
}

#[test]
fn residual_stopping_serves_reference_free_batches() {
    let system = DatasetBuilder::new(200, 8).seed(9).consistent();
    let jobs: Vec<BatchJob> = (0..4)
        .map(|j| {
            let hidden: Vec<f64> = (0..system.cols()).map(|i| (i + j) as f64 - 2.0).collect();
            BatchJob::new(gemv(&system.a, &hidden).unwrap()) // no x_ref attached
        })
        .collect();
    let opts = SolveOptions::default().with_residual_stopping(1e-6, 32);
    let reports = BatchSolver::new(&system, RkSolver::new(3))
        .with_workers(2)
        .solve_many(&jobs, &opts)
        .unwrap();
    for r in &reports {
        assert!(r.result.converged, "job {}: no quality certificate", r.job);
        assert!(r.residual_norm * r.residual_norm < 1e-6, "job {}", r.job);
    }
}
