//! Cross-module integration of the sequential solvers on paper-shaped
//! workloads: consistent + inconsistent data sets, CGLS references, the
//! alpha* pipeline, and dataset IO.

use kaczmarz::data::{io, DatasetBuilder};
use kaczmarz::solvers::alpha::{full_matrix_alpha, partial_matrix_alphas};
use kaczmarz::solvers::cgls::attach_least_squares;
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

#[test]
fn all_solvers_agree_on_the_solution() {
    let sys = DatasetBuilder::new(600, 30).seed(21).consistent();
    let x_true = sys.x_true.clone().unwrap();
    let opts = SolveOptions::default().with_tolerance(1e-12);
    let solvers: Vec<Box<dyn Solver>> = vec![
        Box::new(CkSolver::new()),
        Box::new(RkSolver::new(1)),
        Box::new(RkaSolver::new(1, 4, 1.0)),
        Box::new(RkabSolver::new(1, 4, 30, 1.0)),
    ];
    for s in solvers {
        let r = s.solve(&sys, &opts);
        assert!(r.converged, "{} did not converge", s.name());
        let err: f64 = r
            .x
            .iter()
            .zip(&x_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-5, "{}: err {err}", s.name());
    }
}

#[test]
fn paper_timing_protocol_roundtrip() {
    // The §3.1 protocol: calibrate iterations over seeds, then run timed
    // with fixed iterations — the fixed run must land within tolerance of
    // the converged state.
    let sys = DatasetBuilder::new(500, 25).seed(3).consistent();
    let calibrate = SolveOptions::default().with_tolerance(1e-8);
    let mut total = 0usize;
    for seed in 0..5 {
        let r = RkSolver::new(seed).solve(&sys, &calibrate);
        assert!(r.converged);
        total += r.iterations;
    }
    let avg = total / 5;
    let timed = SolveOptions::default().with_fixed_iterations(avg);
    let r = RkSolver::new(2).solve(&sys, &timed);
    assert_eq!(r.iterations, avg);
    // Near the calibrated tolerance (within 100x — seeds differ).
    assert!(sys.error_sq(&r.x) < 1e-6, "err {}", sys.error_sq(&r.x));
}

#[test]
fn inconsistent_pipeline_cgls_reference_and_horizon() {
    let mut sys = DatasetBuilder::new(800, 20).seed(17).inconsistent();
    attach_least_squares(&mut sys, 1e-12, 10_000).unwrap();
    // RK stalls above the LS solution; RKA with q=20 gets closer.
    let opts = SolveOptions::default().with_fixed_iterations(30_000).with_history_step(1000);
    let rk = RkSolver::new(4).solve(&sys, &opts);
    let rka = RkaSolver::new(4, 20, 1.0).solve(&sys, &opts);
    let rk_tail = rk.history.tail_error(5).unwrap();
    let rka_tail = rka.history.tail_error(5).unwrap();
    assert!(rka_tail < rk_tail, "rka {rka_tail:.3e} vs rk {rk_tail:.3e}");
    // Neither reaches the LS solution exactly.
    assert!(sys.error_sq(&rk.x) > 0.0);
}

#[test]
fn alpha_star_pipeline_reduces_iterations() {
    let sys = DatasetBuilder::new(800, 40).seed(5).consistent();
    let opts = SolveOptions::default();
    let (astar, cost) = full_matrix_alpha(&sys, 8).unwrap();
    assert!(astar > 1.0 && cost > 0.0);
    let unit = RkaSolver::new(2, 8, 1.0).solve(&sys, &opts).iterations;
    let opt = RkaSolver::new(2, 8, astar).solve(&sys, &opts).iterations;
    assert!(opt < unit, "alpha*: {opt} vs unit {unit}");
    // Partial alphas land in the same ballpark (Table 1's observation).
    let (partials, _) = partial_matrix_alphas(&sys, 8).unwrap();
    for p in &partials {
        assert!((p - astar).abs() / astar < 0.2, "partial {p} vs {astar}");
    }
}

#[test]
fn dataset_io_roundtrip_preserves_solution() {
    let mut sys = DatasetBuilder::new(200, 10).seed(9).inconsistent();
    attach_least_squares(&mut sys, 1e-12, 5_000).unwrap();
    let path = std::env::temp_dir().join("kcz_integration_io.bin");
    io::save(&sys, &path).unwrap();
    let back = io::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // Solving the loaded system gives the same result.
    let opts = SolveOptions::default().with_fixed_iterations(2_000);
    let a = RkSolver::new(1).solve(&sys, &opts);
    let b = RkSolver::new(1).solve(&back, &opts);
    assert_eq!(a.x, b.x);
}
