//! Integration of the simulated-MPI layer: Algorithms 2/4 across world
//! sizes, placements, and against their shared-memory/sequential semantics.

use kaczmarz::data::DatasetBuilder;
use kaczmarz::distributed::{DistRka, DistRkab, Placement, SimCluster};
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::sampling::SamplingScheme;
use kaczmarz::solvers::{SolveOptions, Solver};

#[test]
fn dist_rka_converges_across_world_sizes_and_placements() {
    let sys = DatasetBuilder::new(480, 16).seed(1).consistent();
    let opts = SolveOptions::default();
    for np in [1usize, 2, 4, 8, 12] {
        for placement in [Placement::full_node(), Placement::two_per_node()] {
            let cluster = SimCluster::new(np, placement);
            let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
            assert!(r.converged, "np={np} ppn={}", placement.ppn);
            assert!(sys.error_sq(&r.x) < 1e-8);
        }
    }
}

#[test]
fn dist_rka_iterations_match_sequential_partitioned() {
    let sys = DatasetBuilder::new(500, 20).seed(2).consistent();
    let opts = SolveOptions::default();
    for np in [2usize, 4] {
        let cluster = SimCluster::new(np, Placement::two_per_node());
        let dist = DistRka::new(11, 1.0).solve(&sys, &opts, &cluster);
        let seq = RkaSolver::new(11, np, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &opts);
        let diff = (dist.iterations as f64 - seq.iterations as f64).abs() / seq.iterations as f64;
        assert!(diff < 0.01, "np={np}: {} vs {}", dist.iterations, seq.iterations);
    }
}

#[test]
fn dist_rkab_block_amortizes_allreduce() {
    // Fixed row budget: bigger blocks => fewer Allreduces => less modeled
    // comm time (the Fig. 11 mechanism).
    let sys = DatasetBuilder::new(960, 24).seed(3).consistent();
    let total_rows_per_rank = 240;
    let comm_of = |bs: usize| {
        let cluster = SimCluster::new(4, Placement::two_per_node());
        let opts = SolveOptions::default().with_fixed_iterations(total_rows_per_rank / bs);
        let r = DistRkab::new(5, bs, 1.0).solve(&sys, &opts, &cluster);
        r.rank_stats.iter().map(|s| s.comm_seconds).fold(0.0, f64::max)
    };
    let c_small = comm_of(4);
    let c_big = comm_of(48);
    assert!(c_big < c_small / 4.0, "bs=48 comm {c_big:.3e} vs bs=4 {c_small:.3e}");
}

#[test]
fn placement_changes_simulated_time_shape() {
    // Small per-rank working sets: packing a node is cheaper (intra links).
    // That is the Fig. 6a observation.
    let sys = DatasetBuilder::new(480, 16).seed(4).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(300);
    let sim_of = |placement: Placement| {
        let cluster = SimCluster::new(8, placement);
        let r = DistRka::new(7, 1.0).solve(&sys, &opts, &cluster);
        (r.sim_seconds, r.rank_stats.iter().map(|s| s.comm_seconds).fold(0.0, f64::max))
    };
    let (_, comm_packed) = sim_of(Placement::full_node());
    let (_, comm_spread) = sim_of(Placement::two_per_node());
    // Packed placement never crosses a node at np=8 <= 24: cheaper comm.
    assert!(comm_packed < comm_spread, "packed {comm_packed:.3e} spread {comm_spread:.3e}");
}

#[test]
fn contention_penalizes_packed_nodes_for_large_working_sets() {
    // Large per-rank working set *relative to the LLC*: the contention
    // model must make the packed placement's compute slower (the Fig. 6b
    // mechanism). The test system is small, so shrink the modeled LLC
    // rather than blowing up the matrix.
    let sys = DatasetBuilder::new(2400, 100).seed(5).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(50);
    let adj_of = |placement: Placement| {
        let mut cluster = SimCluster::new(12, placement);
        cluster.model.llc_bytes = 100_000.0; // rank working set ~160 KB
        let r = DistRka::new(7, 1.0).solve(&sys, &opts, &cluster);
        let raw: f64 = r.rank_stats.iter().map(|s| s.compute_seconds).sum();
        let adj: f64 = r.rank_stats.iter().map(|s| s.adjusted_compute_seconds).sum();
        adj / raw
    };
    let packed_factor = adj_of(Placement::full_node());
    let spread_factor = adj_of(Placement::two_per_node());
    assert!(
        packed_factor > spread_factor,
        "packed {packed_factor} should exceed spread {spread_factor}"
    );
}

#[test]
fn simcluster_pooled_solves_are_bit_deterministic_and_reuse_workers() {
    // Mirror of the shared-memory pool determinism test: the SimCluster
    // ranks now run as participants of one pool dispatch, so two solves on
    // the same pool must (a) spawn workers once, on warm-up only, and
    // (b) produce bit-identical iterates — any channel reordering into the
    // deterministic Allreduce, stale job, or rank-state leak would show up
    // here.
    use kaczmarz::parallel::WorkerPool;
    use std::sync::Arc;
    let sys = DatasetBuilder::new(240, 12).seed(21).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(60);
    let np = 4;
    let pool = Arc::new(WorkerPool::new());
    let cluster = SimCluster::new(np, Placement::two_per_node()).with_pool(Arc::clone(&pool));

    let first = DistRkab::new(5, 6, 1.0).solve(&sys, &opts, &cluster);
    assert_eq!(pool.worker_count(), np - 1, "first solve spawns the rank threads");
    let second = DistRkab::new(5, 6, 1.0).solve(&sys, &opts, &cluster);
    assert_eq!(pool.worker_count(), np - 1, "second solve reuses parked workers");
    assert_eq!(first.iterations, second.iterations);
    for (a, b) in first.x.iter().zip(&second.x) {
        assert_eq!(a.to_bits(), b.to_bits(), "pooled SimCluster solves differ: {a} vs {b}");
    }

    // DistRka on the same (already warm) pool: still no spawns.
    let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
    assert_eq!(r.iterations, 60);
    assert_eq!(pool.worker_count(), np - 1, "solver switch must not spawn workers");
}

#[test]
fn dist_results_replicated_across_ranks() {
    // After the final Allreduce every rank holds the same x; the collected
    // result must be consistent with solving on any rank.
    let sys = DatasetBuilder::new(240, 12).seed(6).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(100);
    let cluster = SimCluster::new(3, Placement::two_per_node());
    let r = DistRkab::new(9, 6, 1.0).solve(&sys, &opts, &cluster);
    assert_eq!(r.iterations, 100);
    assert_eq!(r.x.len(), 12);
    assert_eq!(r.rank_stats.len(), 3);
}
