//! Storage-backend properties of the solver core: every solve loop —
//! sequential, shared-memory, distributed, batch — must accept CSR sparse
//! storage; a CSR matrix holding exactly the entries of a dense one must
//! agree with it (bitwise on row metadata, to accumulation-order tolerance
//! on iterates); degenerate sparse rows must be rejected up front; and the
//! Arc-sharing discipline must hold across backends and views.

use kaczmarz::batch::{BatchJob, BatchSolver, SolveQueue};
use kaczmarz::data::{DatasetBuilder, LinearSystem, SparseDatasetBuilder};
use kaczmarz::distributed::{DistRka, DistRkab, Placement, SimCluster};
use kaczmarz::linalg::{gemv, CsrMatrix};
use kaczmarz::parallel::{
    AsyRkSolver, AveragingStrategy, BlockSequentialRk, ParallelRka, ParallelRkab,
};
use kaczmarz::rng::Mt19937;
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rek::RekSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::{RkaSolver, Weights};
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SamplingStrategy, SolveOptions, Solver};
use kaczmarz::Error;

/// A dense system and its exact CSR twin: same `b` / `x_true`, `A`
/// compressed entry-for-entry (gaussian entries are never exactly zero, so
/// nothing is dropped). Row norms come off the same 8-lane kernel over the
/// same contiguous values, so sampling weights — and therefore every row
/// sequence a seeded sampler draws — are bitwise-identical between the two.
fn twins(m: usize, n: usize, seed: u32) -> (LinearSystem, LinearSystem) {
    let dense = DatasetBuilder::new(m, n).seed(seed).consistent();
    let csr = CsrMatrix::from_dense(dense.a.as_dense().expect("generator yields dense"));
    let sparse = LinearSystem::new(csr, dense.b.clone(), dense.x_true.clone(), true);
    (dense, sparse)
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn csr_twin_matches_dense_row_metadata_bitwise() {
    let (d, s) = twins(60, 9, 2);
    assert_eq!(d.frobenius_sq.to_bits(), s.frobenius_sq.to_bits());
    for (i, (a, b)) in d.row_norms_sq.iter().zip(&s.row_norms_sq).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row {i} norm");
    }
    // gemv agreement: the dense 8-lane kernel and the stored-entry loop sum
    // in different orders, so this is a tolerance claim, not a bitwise one.
    let x: Vec<f64> = (0..9).map(|j| 0.3 * j as f64 - 1.0).collect();
    let yd = gemv(&d.a, &x).unwrap();
    let ys = gemv(&s.a, &x).unwrap();
    assert!(max_abs_diff(&yd, &ys) < 1e-10, "gemv drift {}", max_abs_diff(&yd, &ys));
}

fn assert_twin_agreement<S: Solver>(name: &str, solver: S, d: &LinearSystem, s: &LinearSystem) {
    // Fixed budget: both runs execute the same iterations whether or not
    // they converge early, so the trajectories stay comparable end to end.
    let opts = SolveOptions::default().with_fixed_iterations(600);
    let rd = solver.solve(d, &opts);
    let rs = solver.solve(s, &opts);
    assert_eq!(rd.iterations, rs.iterations, "{name}: iteration mismatch");
    assert_eq!(rd.rows_used, rs.rows_used, "{name}: rows_used mismatch");
    // Identical row sequences, projection sums in different orders: the
    // iterates may differ in the last bits but nowhere above rounding.
    let drift = max_abs_diff(&rd.x, &rs.x);
    assert!(drift < 1e-8, "{name}: dense/CSR drift {drift}");
}

#[test]
fn sequential_solvers_agree_between_dense_and_csr_twins() {
    let (d, s) = twins(240, 12, 3);
    assert_twin_agreement("rk", RkSolver::new(7), &d, &s);
    assert_twin_agreement("ck", CkSolver::new(), &d, &s);
    assert_twin_agreement("rka", RkaSolver::new(7, 4, 1.0), &d, &s);
    assert_twin_agreement("rkab", RkabSolver::new(7, 4, 6, 1.0), &d, &s);
    // The zoo members ride the same row kernels plus (REK) the column ones;
    // their trajectories must be backend-agnostic too. Greedy selection
    // scans through gemv_block_into, whose dense panel kernel and CSR
    // stored-entry loop sum in different orders — same argmax, drifting
    // last bits — so these stay tolerance claims like the rest.
    assert_twin_agreement("rek", RekSolver::new(7), &d, &s);
    assert_twin_agreement(
        "rk-greedy",
        RkSolver::new(7).with_sampling(SamplingStrategy::Greedy),
        &d,
        &s,
    );
    assert_twin_agreement(
        "rka-norm-weights",
        RkaSolver::new(7, 4, 1.0).with_weights(Weights::InverseRowNorm(1.0)),
        &d,
        &s,
    );
    assert_twin_agreement(
        "rkab-greedy",
        RkabSolver::new(7, 4, 6, 1.0).with_sampling(SamplingStrategy::Greedy),
        &d,
        &s,
    );
}

#[test]
fn csr_twin_matches_dense_column_ops_bitwise() {
    // REK's column kernels: both backends accumulate strictly in row order
    // (dense reads row[j] per row, CSR binary-searches each row's column
    // list), and the twin stores every entry — so unlike the lane-blocked
    // row kernels this is a bitwise claim, not a tolerance one.
    let (d, s) = twins(60, 9, 2);
    for (j, (a, b)) in d.a.col_norms_sq().iter().zip(&s.a.col_norms_sq()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "col {j} norm");
    }
    let y: Vec<f64> = (0..60).map(|i| 0.1 * i as f64 - 2.5).collect();
    for j in 0..9 {
        assert_eq!(
            d.a.col_dot(j, &y).to_bits(),
            s.a.col_dot(j, &y).to_bits(),
            "col_dot {j}"
        );
        let (mut yd, mut ys) = (y.clone(), y.clone());
        d.a.col_axpy(j, 0.7, &mut yd);
        s.a.col_axpy(j, 0.7, &mut ys);
        for (i, (a, b)) in yd.iter().zip(&ys).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "col_axpy {j} row {i}");
        }
    }
}

#[test]
fn shared_memory_engines_converge_on_csr_storage() {
    // Threaded gathers accumulate in scheduler-dependent order, so the
    // cross-backend claim here is convergence to the known solution, not a
    // trajectory match (that is pinned by the sequential test above).
    let (_, s) = twins(300, 10, 4);
    let opts = SolveOptions::default();
    for strategy in [
        AveragingStrategy::Critical,
        AveragingStrategy::Atomic,
        AveragingStrategy::Reduce,
        AveragingStrategy::MatrixGather,
    ] {
        let r = ParallelRka::new(3, 4, 1.0).with_strategy(strategy).solve(&s, &opts);
        assert!(r.converged, "ParallelRka {strategy:?} on CSR");
        assert!(s.error_sq(&r.x) < 1e-8, "ParallelRka {strategy:?} err {}", s.error_sq(&r.x));
    }
    let r = ParallelRkab::new(3, 4, 8, 1.0).solve(&s, &opts);
    assert!(r.converged && s.error_sq(&r.x) < 1e-8, "ParallelRkab on CSR");
    let r = BlockSequentialRk::new(13, 4).solve(&s, &opts);
    assert!(r.converged && s.error_sq(&r.x) < 1e-8, "BlockSequentialRk on CSR");
    let asy_opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(3_000_000);
    let r = AsyRkSolver::new(3, 4).solve(&s, &asy_opts);
    assert!(r.converged, "AsyRk on CSR");
    assert!(s.error_sq(&r.x) < 1e-4, "AsyRk err {}", s.error_sq(&r.x));
}

#[test]
fn distributed_solves_accept_csr_and_sparse_systems() {
    let cluster = SimCluster::new(3, Placement::two_per_node());
    let opts = SolveOptions::default();

    let (_, s) = twins(240, 8, 5);
    let r = DistRka::new(3, 1.0).solve(&s, &opts, &cluster);
    assert!(r.converged, "DistRka on CSR twin");
    assert!(s.error_sq(&r.x) < 1e-8, "DistRka err {}", s.error_sq(&r.x));
    let r = DistRkab::new(5, 6, 1.0).solve(&s, &opts, &cluster);
    assert!(r.converged, "DistRkab on CSR twin");
    assert!(s.error_sq(&r.x) < 1e-8, "DistRkab err {}", s.error_sq(&r.x));

    // A genuinely sparse generator-built system end to end through the
    // simulated cluster: partitioned sampling, rank-local projections, and
    // allreduce all running on stored-entry row kernels.
    let sparse = SparseDatasetBuilder::new(240, 12, 0.5).seed(9).consistent();
    assert!(sparse.a.as_csr().is_some(), "sparse builder must yield CSR storage");
    let r = DistRka::new(7, 1.0).solve(&sparse, &opts, &cluster);
    assert!(r.converged, "DistRka on sparse system");
    assert!(sparse.error_sq(&r.x) < 1e-8, "DistRka sparse err {}", sparse.error_sq(&r.x));
    let r = DistRkab::new(7, 4, 1.0).solve(&sparse, &opts, &cluster);
    assert!(r.converged, "DistRkab on sparse system");
    assert!(sparse.error_sq(&r.x) < 1e-8, "DistRkab sparse err {}", sparse.error_sq(&r.x));
}

#[test]
fn batch_solver_and_queue_accept_csr_storage() {
    let (_, s) = twins(200, 8, 6);
    // Six rhs with known solutions, built through the CSR-backed gemv.
    let mut rng = Mt19937::new(31);
    let jobs: Vec<BatchJob> = (0..6)
        .map(|_| {
            let x: Vec<f64> = (0..s.cols()).map(|_| rng.next_f64() - 0.5).collect();
            BatchJob::new(gemv(&s.a, &x).unwrap()).with_reference(x)
        })
        .collect();
    let reports = BatchSolver::new(&s, RkSolver::new(7))
        .with_workers(3)
        .solve_many(&jobs, &SolveOptions::default())
        .unwrap();
    assert_eq!(reports.len(), 6);
    for (j, report) in reports.iter().enumerate() {
        assert!(report.result.converged, "batch job {j} on CSR");
    }

    // A queue mixing sparse and dense systems in one dispatch: storage is
    // per-job, so heterogeneous backends must coexist in a single run.
    let mut queue = SolveQueue::new().with_workers(3);
    let id_sparse = queue.push(
        SparseDatasetBuilder::new(160, 8, 0.5).seed(12).consistent(),
        SolveOptions::default(),
    );
    let id_dense =
        queue.push(DatasetBuilder::new(160, 8).seed(13).consistent(), SolveOptions::default());
    let reports = queue.run(&RkSolver::new(3)).unwrap();
    assert!(reports[id_sparse].result.converged, "queued sparse job");
    assert!(reports[id_dense].result.converged, "queued dense job");
}

#[test]
fn empty_csr_row_is_rejected_as_degenerate() {
    // Row 1 of 3 stores nothing: ‖A^(1)‖² = 0 and every projection against
    // it would divide by zero, so the strict constructor must refuse it.
    let a = CsrMatrix::from_triplets(3, 4, &[(0, 0, 1.0), (2, 3, 2.0)]).unwrap();
    let err = LinearSystem::try_new(a, vec![1.0; 3], None, true).unwrap_err();
    match err {
        Error::DegenerateRow { row } => assert_eq!(row, 1),
        other => panic!("expected DegenerateRow, got {other:?}"),
    }
    // An explicitly stored zero degenerates the row just the same.
    let a = CsrMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 0.0)]).unwrap();
    let err = LinearSystem::try_new(a, vec![1.0; 2], None, true).unwrap_err();
    assert!(matches!(err, Error::DegenerateRow { row: 1 }), "stored zero row");
}

#[test]
fn clones_and_row_blocks_share_storage_in_both_backends() {
    let sparse = SparseDatasetBuilder::new(40, 10, 0.3).seed(8).consistent();
    assert!(sparse.clone().a.shares_storage(&sparse.a), "CSR clone must be refcount bumps");
    let block = sparse.a.row_block(8, 24).unwrap();
    assert_eq!(block.rows(), 16);
    assert!(block.shares_storage(&sparse.a), "CSR row block must alias parent entries");

    let dense = DatasetBuilder::new(40, 10).seed(8).consistent();
    let block = dense.a.row_block(8, 24).unwrap();
    assert_eq!(block.rows(), 16);
    assert!(block.shares_storage(&dense.a), "dense row block must alias parent buffer");

    // Dense and CSR never alias each other, whatever their contents.
    assert!(!sparse.a.shares_storage(&dense.a), "cross-backend sharing is impossible");
}
