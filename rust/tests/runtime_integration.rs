//! Integration: artifacts -> PJRT -> numerics vs the native solvers.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use kaczmarz::data::DatasetBuilder;
use kaczmarz::runtime::{ArtifactKind, Manifest, PjrtEngine, PjrtRkabSolver};
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_lists_all_kinds() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.entries().iter().any(|e| e.kind == ArtifactKind::RkaStep));
    assert!(m.entries().iter().any(|e| e.kind == ArtifactKind::RkabBlock));
    assert!(m.entries().iter().any(|e| e.kind == ArtifactKind::RkabRound));
}

#[test]
fn engine_compiles_and_runs_rka_step() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let entry = engine.find(ArtifactKind::RkaStep, 4, 1, 256).unwrap();
    let (q, n) = (entry.q, entry.n);

    // Identity-ish check: x = 0, rows = unit vectors e_0..e_3, b = 1 =>
    // update = (alpha/q) * sum e_i.
    let mut a = vec![0.0f64; q * n];
    for t in 0..q {
        a[t * n + t] = 1.0;
    }
    let b = vec![1.0f64; q];
    let inv_norms = vec![1.0f64; q];
    let x = vec![0.0f64; n];
    let alpha_over_q = [1.0 / q as f64];

    let inputs = [
        PjrtEngine::literal(&a, &[q as i64, n as i64]).unwrap(),
        PjrtEngine::literal(&b, &[q as i64]).unwrap(),
        PjrtEngine::literal(&inv_norms, &[q as i64]).unwrap(),
        PjrtEngine::literal(&x, &[n as i64]).unwrap(),
        PjrtEngine::literal(&alpha_over_q, &[1]).unwrap(),
    ];
    let out = engine.run(&entry.name, &inputs).unwrap();
    assert_eq!(out.len(), n);
    for t in 0..q {
        assert!((out[t] - 0.25).abs() < 1e-12, "out[{t}] = {}", out[t]);
    }
    for j in q..n {
        assert_eq!(out[j], 0.0);
    }
}

#[test]
fn pjrt_rkab_matches_native_rkab() {
    // The headline composition test: same seed => same sampled rows =>
    // same iterates as the native solver, up to f64 reassociation inside
    // the XLA-compiled reduction.
    let Some(dir) = artifacts_dir() else { return };
    let (q, bs, n) = (4, 64, 256);
    let sys = DatasetBuilder::new(2000, n).seed(5).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(20);

    let pjrt = PjrtRkabSolver::new(&dir, 9, q, bs, n, 1.0).unwrap();
    let got = pjrt.solve(&sys, &opts).unwrap();
    let native = RkabSolver::new(9, q, bs, 1.0).solve(&sys, &opts);

    let drift: f64 =
        got.x.iter().zip(&native.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    let scale = native.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
    assert!(drift < 1e-8 * scale.max(1.0), "drift {drift} (scale {scale})");
    assert_eq!(got.rows_used, native.rows_used);
}

#[test]
fn pjrt_rkab_converges_to_solution() {
    let Some(dir) = artifacts_dir() else { return };
    let (q, bs, n) = (4, 256, 256);
    let sys = DatasetBuilder::new(4000, n).seed(7).consistent();
    let opts = SolveOptions::default().with_tolerance(1e-8).with_max_iterations(2000);
    let pjrt = PjrtRkabSolver::new(&dir, 3, q, bs, n, 1.0).unwrap();
    let r = pjrt.solve(&sys, &opts).unwrap();
    assert!(r.converged, "did not converge in {} iterations", r.iterations);
    assert!(sys.error_sq(&r.x) < 1e-8);
}

#[test]
fn missing_shape_is_clear_error() {
    let Some(dir) = artifacts_dir() else { return };
    let err = match PjrtRkabSolver::new(&dir, 1, 13, 999, 123, 1.0) {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    let msg = err.to_string();
    assert!(msg.contains("artifact not found"), "got: {msg}");
}
