//! SIMD-vs-scalar kernel equivalence properties.
//!
//! The scalar 8-lane kernels are the crate's bitwise reference path; the
//! AVX2+FMA kernels in `linalg::simd` may legally differ in the last ulps
//! (FMA contracts `a*b + c` into one rounding), so cross-flavor agreement
//! is asserted to a *relative tolerance*, never `to_bits`. Within a
//! flavor, the fused `axpy_dot` must stay bitwise-equal to its separate
//! `axpy` + `dot` pair — that contract is checked for both flavors here.
//!
//! The explicit `*_avx2` wrappers run whenever the *host* supports the
//! features, independent of the process-wide dispatch, which is what lets
//! one test process compare both flavors side by side. Hosts without
//! AVX2+FMA run the scalar assertions only (the wrappers return
//! `None`/`false`), so the suite passes everywhere.

use kaczmarz::linalg::simd::{axpy_avx2, axpy_dot_avx2, dot_avx2};
use kaczmarz::linalg::{
    active_flavor, axpy, axpy_dot, axpy_dot_scalar, axpy_scalar, detected_flavor, dot, dot_scalar,
    KernelFlavor,
};

/// Relative-error gate for cross-flavor comparisons. FMA reassociation
/// over a few hundred elements stays far inside 1e-12 for the
/// well-conditioned inputs used here.
const REL_TOL: f64 = 1e-12;

fn rel_err(got: f64, reference: f64) -> f64 {
    (got - reference).abs() / reference.abs().max(1e-30)
}

/// Deterministic, sign-mixed test vectors of length `n`.
fn vectors(n: usize, phase: f64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let a = (0..n).map(|i| (i as f64 * 0.7 + phase).sin() * 1.5).collect();
    let b = (0..n).map(|i| (i as f64 * 0.3 - phase).cos() * 0.8).collect();
    let c = (0..n).map(|i| ((i % 13) as f64 - 6.0) * 0.21 + phase).collect();
    (a, b, c)
}

#[test]
fn simd_matches_scalar_across_all_remainder_lengths() {
    // Every tail length `n mod 8` in {0..7}, at several multiples of the
    // 8-lane trip, plus the empty slice.
    for base in [0usize, 8, 16, 64, 248] {
        for rem in 0..8usize {
            let n = base + rem;
            let (a, b, z) = vectors(n, 0.37);
            let reference = dot_scalar(&a, &b);
            if let Some(d) = dot_avx2(&a, &b) {
                assert!(
                    rel_err(d, reference) < REL_TOL || (n == 0 && d == reference),
                    "dot n={n}: simd {d:e} vs scalar {reference:e}"
                );
            }

            let mut y_scalar: Vec<f64> = b.clone();
            axpy_scalar(0.73, &a, &mut y_scalar);
            let mut y_simd: Vec<f64> = b.clone();
            if axpy_avx2(0.73, &a, &mut y_simd) {
                for (i, (u, v)) in y_simd.iter().zip(&y_scalar).enumerate() {
                    assert!(rel_err(*u, *v) < REL_TOL, "axpy n={n} elem {i}: {u:e} vs {v:e}");
                }
            }

            let mut y_scalar: Vec<f64> = b.clone();
            let d_scalar = axpy_dot_scalar(0.73, &a, &z, &mut y_scalar);
            let mut y_simd: Vec<f64> = b.clone();
            if let Some(d_simd) = axpy_dot_avx2(0.73, &a, &z, &mut y_simd) {
                assert!(
                    rel_err(d_simd, d_scalar) < REL_TOL || n == 0,
                    "axpy_dot n={n}: simd {d_simd:e} vs scalar {d_scalar:e}"
                );
                for (i, (u, v)) in y_simd.iter().zip(&y_scalar).enumerate() {
                    assert!(rel_err(*u, *v) < REL_TOL, "axpy_dot y n={n} elem {i}");
                }
            }
        }
    }
}

#[test]
fn fused_kernel_is_bitwise_separate_within_each_flavor() {
    // The fused/separate identity is a *within-flavor* bitwise contract:
    // each flavor's axpy_dot mirrors its own dot's accumulator layout.
    let n = 67; // 8 full trips + a 3-element tail
    let (a, b, z) = vectors(n, 1.13);

    let mut y_sep = b.clone();
    axpy_scalar(0.41, &a, &mut y_sep);
    let d_sep = dot_scalar(&z, &y_sep);
    let mut y_fused = b.clone();
    let d_fused = axpy_dot_scalar(0.41, &a, &z, &mut y_fused);
    assert_eq!(d_fused.to_bits(), d_sep.to_bits(), "scalar fused dot");
    for (u, v) in y_fused.iter().zip(&y_sep) {
        assert_eq!(u.to_bits(), v.to_bits(), "scalar fused y");
    }

    let mut y_sep = b.clone();
    if axpy_avx2(0.41, &a, &mut y_sep) {
        let d_sep = dot_avx2(&z, &y_sep).unwrap();
        let mut y_fused = b.clone();
        let d_fused = axpy_dot_avx2(0.41, &a, &z, &mut y_fused).unwrap();
        assert_eq!(d_fused.to_bits(), d_sep.to_bits(), "simd fused dot");
        for (u, v) in y_fused.iter().zip(&y_sep) {
            assert_eq!(u.to_bits(), v.to_bits(), "simd fused y");
        }
    }
}

#[test]
fn simd_handles_subnormal_inputs() {
    // Subnormal elements mixed into otherwise-normal vectors: FMA keeps
    // the full a*b product where the scalar path may flush the
    // intermediate to a subnormal/zero, so agreement here is the
    // tolerance gate working exactly as specified (the normal elements
    // dominate the accumulators).
    let n = 37;
    let (mut a, mut b, z) = vectors(n, 2.71);
    a[3] = 5e-324; // smallest positive subnormal
    a[11] = -1e-310;
    a[20] = f64::MIN_POSITIVE / 4.0;
    b[3] = 1e-310;
    b[11] = 4.9e-324;
    let reference = dot_scalar(&a, &b);
    assert!(reference.is_finite());
    if let Some(d) = dot_avx2(&a, &b) {
        assert!(rel_err(d, reference) < REL_TOL, "subnormal dot: {d:e} vs {reference:e}");
    }
    let mut y_scalar = b.clone();
    let d_scalar = axpy_dot_scalar(1e-320, &a, &z, &mut y_scalar);
    let mut y_simd = b.clone();
    if let Some(d_simd) = axpy_dot_avx2(1e-320, &a, &z, &mut y_simd) {
        assert!(rel_err(d_simd, d_scalar) < REL_TOL);
        for (u, v) in y_simd.iter().zip(&y_scalar) {
            assert!(rel_err(*u, *v) < REL_TOL);
        }
    }
}

#[test]
fn dispatched_kernels_are_bitwise_one_of_the_flavors() {
    // Smoke test for the dispatch layer itself: whatever active_flavor()
    // resolved to, the undecorated entry points must produce bitwise the
    // output of that flavor's explicit kernel — dispatch adds a branch,
    // never a numeric change.
    let n = 129;
    let (a, b, z) = vectors(n, 0.05);
    let disp_dot = dot(&a, &b);
    let mut disp_y = b.clone();
    axpy(0.29, &a, &mut disp_y);
    let mut disp_yf = b.clone();
    let disp_fused = axpy_dot(0.29, &a, &z, &mut disp_yf);
    match active_flavor() {
        KernelFlavor::Scalar => {
            assert_eq!(disp_dot.to_bits(), dot_scalar(&a, &b).to_bits());
            let mut y = b.clone();
            axpy_scalar(0.29, &a, &mut y);
            assert_eq!(disp_y, y);
            let mut yf = b.clone();
            let f = axpy_dot_scalar(0.29, &a, &z, &mut yf);
            assert_eq!(disp_fused.to_bits(), f.to_bits());
            assert_eq!(disp_yf, yf);
        }
        KernelFlavor::Avx2Fma => {
            assert_eq!(detected_flavor(), KernelFlavor::Avx2Fma, "dispatch must be clamped");
            assert_eq!(disp_dot.to_bits(), dot_avx2(&a, &b).unwrap().to_bits());
            let mut y = b.clone();
            assert!(axpy_avx2(0.29, &a, &mut y));
            assert_eq!(disp_y, y);
            let mut yf = b.clone();
            let f = axpy_dot_avx2(0.29, &a, &z, &mut yf).unwrap();
            assert_eq!(disp_fused.to_bits(), f.to_bits());
            assert_eq!(disp_yf, yf);
        }
    }
}

/// The forced-`scalar` override, proven end to end in a child process
/// (dispatch is pinned per process by a `OnceLock`, so the override can
/// only be observed from a process that starts with it).
///
/// The parent re-execs this same test binary filtered to this one test,
/// with `KACZMARZ_KERNEL=scalar` (env route) and then with
/// `KACZMARZ_SIMD_CHILD=force` (programmatic `force_flavor` route); each
/// child asserts the dispatched kernels are bitwise the scalar reference.
#[test]
fn forced_scalar_override_dispatches_scalar_kernels() {
    match std::env::var("KACZMARZ_SIMD_CHILD").as_deref() {
        Ok("env") => {
            // Parent set KACZMARZ_KERNEL=scalar for this process.
            assert_eq!(active_flavor(), KernelFlavor::Scalar, "env override ignored");
            assert_dispatch_is_scalar_bitwise();
            return;
        }
        Ok("force") => {
            // No env override: pin programmatically before first use.
            assert!(
                kaczmarz::linalg::force_flavor(KernelFlavor::Scalar),
                "force_flavor(Scalar) must win in a fresh process"
            );
            assert_eq!(active_flavor(), KernelFlavor::Scalar);
            assert_dispatch_is_scalar_bitwise();
            return;
        }
        _ => {}
    }
    let exe = std::env::current_exe().expect("test binary path");
    for (child_mode, kernel_env) in [("env", Some("scalar")), ("force", None)] {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("forced_scalar_override_dispatches_scalar_kernels")
            .arg("--exact")
            .env("KACZMARZ_SIMD_CHILD", child_mode);
        match kernel_env {
            Some(v) => cmd.env("KACZMARZ_KERNEL", v),
            None => cmd.env_remove("KACZMARZ_KERNEL"),
        };
        let out = cmd.output().expect("spawn forced-scalar child");
        assert!(
            out.status.success(),
            "forced-scalar child ({child_mode}) failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

/// Shared body for the forced-scalar children: every dispatched kernel
/// must be bitwise the scalar reference.
fn assert_dispatch_is_scalar_bitwise() {
    let (a, b, z) = vectors(53, 0.9);
    assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits());
    let mut y_disp = b.clone();
    axpy(0.61, &a, &mut y_disp);
    let mut y_ref = b.clone();
    axpy_scalar(0.61, &a, &mut y_ref);
    assert_eq!(y_disp, y_ref);
    let mut yf_disp = b.clone();
    let d_disp = axpy_dot(0.61, &a, &z, &mut yf_disp);
    let mut yf_ref = b;
    let d_ref = axpy_dot_scalar(0.61, &a, &z, &mut yf_ref);
    assert_eq!(d_disp.to_bits(), d_ref.to_bits());
    assert_eq!(yf_disp, yf_ref);
}
