#!/usr/bin/env python3
"""Compare a fresh BENCH_micro.json against the committed baseline.

Usage: compare_bench.py BASELINE FRESH [--band RATIO]

Two very different kinds of comparison happen here, with very different
teeth:

- **Timing rows** (per-op ns/iter from the bench table) are advisory.
  Rows whose ns/op drifts beyond the noise band (default 3x either way
  — CI runners wobble hugely on micro timings) are printed as warnings
  so a human can spot a real regression in the job log, but they never
  fail the job.
- **Equivalence flags** (the bitwise-exactness checks) gate hard: a
  check that passes in the baseline and fails — or disappears — in the
  fresh run exits nonzero. These are deterministic claims, not timings.

Refresh the baseline by downloading the BENCH_micro artifact from a
green main run and committing it as BENCH_baseline.json.
"""

import json
import sys


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except OSError as e:
        sys.exit(f"compare_bench: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"compare_bench: {path} is not valid JSON: {e}")


def row_key(row):
    return (row.get("operation", ""), row.get("n", ""))


def ns_per_op(row):
    try:
        v = float(row.get("ns/op", ""))
    except ValueError:
        return None
    return v if v > 0 else None


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--band")]
    band = 3.0
    for a in argv[1:]:
        if a.startswith("--band="):
            band = float(a.split("=", 1)[1])
    if len(args) != 2:
        sys.exit(__doc__)
    base, fresh = load(args[0]), load(args[1])

    base_rows = {row_key(r): r for r in base.get("rows", [])}
    warned = 0
    for r in fresh.get("rows", []):
        op, n = row_key(r)
        b = base_rows.get((op, n))
        if b is None:
            print(f"note: no baseline for {op!r} (n={n})")
            continue
        fresh_ns, base_ns = ns_per_op(r), ns_per_op(b)
        if fresh_ns is None or base_ns is None:
            continue
        ratio = fresh_ns / base_ns
        if ratio > band or ratio < 1.0 / band:
            direction = "slower" if ratio > 1 else "faster"
            print(
                f"WARN: {op!r} (n={n}) {ratio:.2f}x {direction} than baseline "
                f"({fresh_ns:.1f} vs {base_ns:.1f} ns/op; band {band}x, advisory only)"
            )
            warned += 1
    if warned:
        print(f"{warned} timing row(s) outside the noise band (advisory, not failing)")

    fresh_checks = {c.get("name"): bool(c.get("pass")) for c in fresh.get("checks", [])}
    regressions = []
    for c in base.get("checks", []):
        name, passed = c.get("name"), bool(c.get("pass"))
        if not passed:
            continue  # a baseline that records a failure gates nothing
        if name not in fresh_checks:
            regressions.append(f"{name} (missing from fresh run)")
        elif not fresh_checks[name]:
            regressions.append(name)
    if regressions:
        print("EQUIVALENCE REGRESSIONS vs baseline:")
        for name in regressions:
            print(f"  - {name}")
        sys.exit(1)
    print(f"equivalence flags: {len(fresh_checks)} fresh, no regressions vs baseline")


if __name__ == "__main__":
    main(sys.argv)
