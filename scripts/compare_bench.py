#!/usr/bin/env python3
"""Compare a fresh BENCH_micro.json against the committed baseline.

Usage: compare_bench.py BASELINE FRESH [--band=RATIO] [--trend-band=RATIO]
                        [--previous=PREV]

Two very different kinds of comparison happen here, with very different
teeth:

- **Timing rows** (per-op ns/iter from the bench table) are advisory.
  Rows whose ns/op drifts beyond the noise band (default 3x either way
  — CI runners wobble hugely on micro timings) are printed as warnings
  so a human can spot a real regression in the job log, but they never
  fail the job.
- **Equivalence flags** (the bitwise-exactness checks, plus the
  tolerance-gated simd-vs-scalar kernel flags) gate hard: a check that
  passes in the baseline and fails — or disappears — in the fresh run
  exits nonzero. These are deterministic claims, not timings.

With `--previous=PREV` the fresh run is additionally compared against
the previous green run's artifact (downloaded from CI, not committed).
That comparison prints `TREND:` lines for run-over-run drift beyond
`--trend-band` (default 2x — consecutive runs on the same runner fleet
are less noisy than runs against a months-old committed file) and is
**always warn-only**: the hard gate stays anchored to the committed
baseline so a slow regression cannot ratchet itself green one small
step at a time.

Every run is stamped with the kernel flavor that produced it: the
top-level "kernel" field records what the dispatched (untagged) rows ran
under ("scalar" or "avx2+fma"), and the flavor-explicit rows carry
theirs in the operation name ("dot [simd]" / "dot [scalar]"). Timing
rows are only compared when both runs used the same flavor —
a simd-vs-scalar delta is a hardware/dispatch difference, not drift —
and every warning names the flavor it was measured under.

Refresh the committed baseline by downloading the BENCH_micro artifact
from a green main run and committing it as BENCH_baseline.json; it is
the cold-start anchor when no previous artifact exists.
"""

import json
import sys


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except OSError as e:
        sys.exit(f"compare_bench: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"compare_bench: {path} is not valid JSON: {e}")


def row_key(row):
    return (row.get("operation", ""), row.get("n", ""))


def run_flavor(doc):
    """Normalized flavor of a run's dispatched rows: scalar / simd / unknown."""
    name = doc.get("kernel")
    if name is None:
        return "unknown"  # pre-flavor-stamp baseline
    return "scalar" if name == "scalar" else "simd"


def row_flavor(row, default):
    """Which kernel flavor produced a row's timing."""
    op = row.get("operation", "")
    if "[simd]" in op:
        return "simd"
    if "[scalar]" in op:
        return "scalar"
    return default


def ns_per_op(row):
    try:
        v = float(row.get("ns/op", ""))
    except ValueError:
        return None
    return v if v > 0 else None


def drift_rows(old, new, band, label, prefix, note_missing):
    """Print per-row timing drift of `new` vs `old` beyond `band`.

    Advisory in both callers: returns the warning count, never exits.
    `label` names the reference run in messages; `prefix` tags each line
    (WARN for the committed baseline, TREND for the previous artifact).
    """
    old_kernel, new_kernel = run_flavor(old), run_flavor(new)
    old_rows = {row_key(r): r for r in old.get("rows", [])}
    warned = 0
    cross_flavor = 0
    for r in new.get("rows", []):
        op, n = row_key(r)
        b = old_rows.get((op, n))
        if b is None:
            if note_missing:
                print(f"note: no {label} for {op!r} (n={n})")
            continue
        bf, ff = row_flavor(b, old_kernel), row_flavor(r, new_kernel)
        if "unknown" not in (bf, ff) and bf != ff:
            # A simd-vs-scalar delta is a dispatch difference, not drift.
            cross_flavor += 1
            continue
        fresh_ns, old_ns = ns_per_op(r), ns_per_op(b)
        if fresh_ns is None or old_ns is None:
            continue
        ratio = fresh_ns / old_ns
        if ratio > band or ratio < 1.0 / band:
            direction = "slower" if ratio > 1 else "faster"
            print(
                f"{prefix}: {op!r} (n={n}, kernel={ff}) {ratio:.2f}x {direction} than {label} "
                f"({fresh_ns:.1f} vs {old_ns:.1f} ns/op; band {band}x, advisory only)"
            )
            warned += 1
    if cross_flavor:
        print(
            f"{cross_flavor} row(s) skipped vs {label}: {label} ({old_kernel}) and fresh "
            f"({new_kernel}) ran different kernel flavors"
        )
    return warned


def main(argv):
    band = 3.0
    trend_band = 2.0
    previous_path = None
    positional = []
    for a in argv[1:]:
        if a.startswith("--band="):
            band = float(a.split("=", 1)[1])
        elif a.startswith("--trend-band="):
            trend_band = float(a.split("=", 1)[1])
        elif a.startswith("--previous="):
            previous_path = a.split("=", 1)[1]
        elif a.startswith("--"):
            sys.exit(f"compare_bench: unknown option {a!r}\n\n{__doc__}")
        else:
            positional.append(a)
    if len(positional) != 2:
        sys.exit(__doc__)
    base, fresh = load(positional[0]), load(positional[1])

    base_kernel, fresh_kernel = run_flavor(base), run_flavor(fresh)
    print(
        f"kernel flavor of dispatched rows: baseline={base_kernel}, fresh={fresh_kernel}"
    )

    warned = drift_rows(base, fresh, band, "baseline", "WARN", note_missing=True)
    if warned:
        print(f"{warned} timing row(s) outside the noise band (advisory, not failing)")

    # Run-over-run trend vs the previous green run's artifact: tighter
    # band, warn-only — the hard gate below stays vs the committed
    # baseline so drift cannot ratchet itself green.
    if previous_path is not None:
        prev = load(previous_path)
        trends = drift_rows(
            prev, fresh, trend_band, "previous run", "TREND", note_missing=False
        )
        if trends:
            print(
                f"{trends} timing row(s) drifted vs the previous green run "
                f"(band {trend_band}x, advisory only)"
            )
        else:
            print(
                f"trend vs previous green run: all rows within {trend_band}x"
            )

    fresh_checks = {c.get("name"): bool(c.get("pass")) for c in fresh.get("checks", [])}
    regressions = []
    for c in base.get("checks", []):
        name, passed = c.get("name"), bool(c.get("pass"))
        if not passed:
            continue  # a baseline that records a failure gates nothing
        if name not in fresh_checks:
            regressions.append(f"{name} (missing from fresh run)")
        elif not fresh_checks[name]:
            regressions.append(name)
    if regressions:
        print("EQUIVALENCE REGRESSIONS vs baseline:")
        for name in regressions:
            print(f"  - {name}")
        sys.exit(1)
    print(f"equivalence flags: {len(fresh_checks)} fresh, no regressions vs baseline")


if __name__ == "__main__":
    main(sys.argv)
