#!/usr/bin/env python3
"""Compare a fresh BENCH_micro.json against the committed baseline.

Usage: compare_bench.py BASELINE FRESH [--band RATIO]

Two very different kinds of comparison happen here, with very different
teeth:

- **Timing rows** (per-op ns/iter from the bench table) are advisory.
  Rows whose ns/op drifts beyond the noise band (default 3x either way
  — CI runners wobble hugely on micro timings) are printed as warnings
  so a human can spot a real regression in the job log, but they never
  fail the job.
- **Equivalence flags** (the bitwise-exactness checks, plus the
  tolerance-gated simd-vs-scalar kernel flags) gate hard: a check that
  passes in the baseline and fails — or disappears — in the fresh run
  exits nonzero. These are deterministic claims, not timings.

Every run is stamped with the kernel flavor that produced it: the
top-level "kernel" field records what the dispatched (untagged) rows ran
under ("scalar" or "avx2+fma"), and the flavor-explicit rows carry
theirs in the operation name ("dot [simd]" / "dot [scalar]"). Timing
rows are only compared when baseline and fresh ran the same flavor —
a simd-vs-scalar delta is a hardware/dispatch difference, not drift —
and every warning names the flavor it was measured under.

Refresh the baseline by downloading the BENCH_micro artifact from a
green main run and committing it as BENCH_baseline.json.
"""

import json
import sys


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except OSError as e:
        sys.exit(f"compare_bench: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"compare_bench: {path} is not valid JSON: {e}")


def row_key(row):
    return (row.get("operation", ""), row.get("n", ""))


def run_flavor(doc):
    """Normalized flavor of a run's dispatched rows: scalar / simd / unknown."""
    name = doc.get("kernel")
    if name is None:
        return "unknown"  # pre-flavor-stamp baseline
    return "scalar" if name == "scalar" else "simd"


def row_flavor(row, default):
    """Which kernel flavor produced a row's timing."""
    op = row.get("operation", "")
    if "[simd]" in op:
        return "simd"
    if "[scalar]" in op:
        return "scalar"
    return default


def ns_per_op(row):
    try:
        v = float(row.get("ns/op", ""))
    except ValueError:
        return None
    return v if v > 0 else None


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--band")]
    band = 3.0
    for a in argv[1:]:
        if a.startswith("--band="):
            band = float(a.split("=", 1)[1])
    if len(args) != 2:
        sys.exit(__doc__)
    base, fresh = load(args[0]), load(args[1])

    base_kernel, fresh_kernel = run_flavor(base), run_flavor(fresh)
    print(
        f"kernel flavor of dispatched rows: baseline={base_kernel}, fresh={fresh_kernel}"
    )

    base_rows = {row_key(r): r for r in base.get("rows", [])}
    warned = 0
    cross_flavor = 0
    for r in fresh.get("rows", []):
        op, n = row_key(r)
        b = base_rows.get((op, n))
        if b is None:
            print(f"note: no baseline for {op!r} (n={n})")
            continue
        bf, ff = row_flavor(b, base_kernel), row_flavor(r, fresh_kernel)
        if "unknown" not in (bf, ff) and bf != ff:
            # A simd-vs-scalar delta is a dispatch difference, not drift.
            cross_flavor += 1
            continue
        fresh_ns, base_ns = ns_per_op(r), ns_per_op(b)
        if fresh_ns is None or base_ns is None:
            continue
        ratio = fresh_ns / base_ns
        if ratio > band or ratio < 1.0 / band:
            direction = "slower" if ratio > 1 else "faster"
            print(
                f"WARN: {op!r} (n={n}, kernel={ff}) {ratio:.2f}x {direction} than baseline "
                f"({fresh_ns:.1f} vs {base_ns:.1f} ns/op; band {band}x, advisory only)"
            )
            warned += 1
    if cross_flavor:
        print(
            f"{cross_flavor} row(s) skipped: baseline ({base_kernel}) and fresh "
            f"({fresh_kernel}) ran different kernel flavors"
        )
    if warned:
        print(f"{warned} timing row(s) outside the noise band (advisory, not failing)")

    fresh_checks = {c.get("name"): bool(c.get("pass")) for c in fresh.get("checks", [])}
    regressions = []
    for c in base.get("checks", []):
        name, passed = c.get("name"), bool(c.get("pass"))
        if not passed:
            continue  # a baseline that records a failure gates nothing
        if name not in fresh_checks:
            regressions.append(f"{name} (missing from fresh run)")
        elif not fresh_checks[name]:
            regressions.append(name)
    if regressions:
        print("EQUIVALENCE REGRESSIONS vs baseline:")
        for name in regressions:
            print(f"  - {name}")
        sys.exit(1)
    print(f"equivalence flags: {len(fresh_checks)} fresh, no regressions vs baseline")


if __name__ == "__main__":
    main(sys.argv)
