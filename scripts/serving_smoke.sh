#!/usr/bin/env bash
# End-to-end smoke test of the serving front end (the `serving-smoke` CI
# job): boot `kaczmarz serve` with small resident systems, then drive
# `kaczmarz submit` clients through the three behaviors the wire protocol
# promises — streamed mid-solve samples, typed deadline errors that do
# not poison the lanes, and mid-solve cancellation. Every client runs
# under `timeout`, so a hung server fails the job instead of wedging CI.
#
# Env: KACZMARZ_BIN (default target/release/kaczmarz),
#      SMOKE_PORT (default 7171).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN="${KACZMARZ_BIN:-target/release/kaczmarz}"
PORT="${SMOKE_PORT:-7171}"
ADDR="127.0.0.1:${PORT}"
LOG="$(mktemp /tmp/serving_smoke.XXXXXX.log)"
SERVER_PID=""

die() {
    echo "serving-smoke: FAIL: $*" >&2
    echo "---- server log ($LOG) ----" >&2
    cat "$LOG" >&2 || true
    exit 1
}

cleanup() {
    if [ -n "$SERVER_PID" ]; then
        kill "$SERVER_PID" 2>/dev/null || true
        wait "$SERVER_PID" 2>/dev/null || true
    fi
}
trap cleanup EXIT

[ -x "$BIN" ] || die "server binary not found at $BIN (build: cargo build --release -p kaczmarz)"

# Boot the server with two small resident systems (consistent, so the
# normal job genuinely converges) on 2 lanes.
"$BIN" serve --addr "$ADDR" --lanes 2 --max-pending 32 \
    --preload "demo:400x24:1,tiny:120x8:2" >"$LOG" 2>&1 &
SERVER_PID=$!

# Readiness gate: the server prints "serving on ADDR" to stdout (always
# line-flushed) once the accept loop is live. 30 s is an eternity for a
# prebuilt release binary to boot.
for _ in $(seq 1 150); do
    grep -q "serving on" "$LOG" && break
    kill -0 "$SERVER_PID" 2>/dev/null || die "server exited during boot"
    sleep 0.2
done
grep -q "serving on" "$LOG" || die "server never printed its readiness banner"
echo "serving-smoke: server up on $ADDR"

echo "== scenario 1: normal job streams >= 2 mid-solve samples and converges"
timeout 120 "$BIN" submit --addr "$ADDR" --system demo --tol 1e-10 --check 4 \
    --min-samples 2 \
    || die "scenario 1 (normal streaming job) exited $?"

echo "== scenario 2: past-deadline job fails with the typed deadline error"
timeout 120 "$BIN" submit --addr "$ADDR" --system demo --tol 0 --check 4 \
    --max-iterations 4000000000 --deadline-ms 1 --expect-error deadline \
    || die "scenario 2 (deadline) exited $?"

echo "== scenario 2b: a sibling job right after the deadline miss still completes"
timeout 120 "$BIN" submit --addr "$ADDR" --system tiny --tol 1e-10 --check 4 \
    || die "scenario 2b (post-deadline sibling) exited $?"

echo "== scenario 3: cancel mid-solve yields the typed cancelled error"
timeout 120 "$BIN" submit --addr "$ADDR" --system demo --tol 0 --check 4 \
    --max-iterations 4000000000 --cancel-after 2 --expect-error cancelled \
    || die "scenario 3 (mid-solve cancel) exited $?"

echo "serving-smoke: all scenarios passed"
