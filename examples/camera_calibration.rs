//! Camera calibration by Direct Linear Transformation — the paper's first
//! motivating application (§1, Gremban et al.).
//!
//! A pinhole camera projects 3D world points X to 2D image points x via a
//! 3x4 matrix P: x ~ P X. Each observed correspondence contributes two
//! linear equations in P's 11 unknowns (12 entries, fixed scale), so with
//! many noisy observations we get an overdetermined inconsistent system —
//! solved here with RK and RKAB and compared against the CGLS least-squares
//! fit.
//!
//! Run: `cargo run --release --example camera_calibration`

use kaczmarz::data::LinearSystem;
use kaczmarz::linalg::Matrix;
use kaczmarz::rng::{Mt19937, NormalSampler};
use kaczmarz::solvers::cgls::attach_least_squares;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

/// Ground-truth projection matrix (intrinsics x extrinsics), scale fixed by
/// p_34 = 1 so the DLT system has 11 unknowns.
fn true_projection() -> [f64; 12] {
    // f = 800 px, principal point (320, 240), camera rotated slightly and
    // translated back 5 units.
    let (c, s) = (0.995f64, 0.0998f64); // ~5.7 degrees
    // K * [R | t] flattened row-major, then normalized by entry (3,4).
    let p = [
        800.0 * c, 0.0, 800.0 * s + 320.0 * 1.0, 320.0 * 5.0,
        240.0 * 0.0, 800.0, 240.0 * 1.0, 240.0 * 5.0,
        -s, 0.0, c, 5.0,
    ];
    let scale = p[11];
    let mut out = [0.0; 12];
    for (i, v) in p.iter().enumerate() {
        out[i] = v / scale;
    }
    out
}

fn main() {
    let p = true_projection();
    let n_points = 600; // 1200 equations, 11 unknowns
    println!("camera calibration: {n_points} observed 3D-2D correspondences");

    let mut rng = Mt19937::new(11);
    let mut noise = NormalSampler::new();
    let mut rows: Vec<f64> = Vec::with_capacity(2 * n_points * 11);
    let mut b: Vec<f64> = Vec::with_capacity(2 * n_points);

    for _ in 0..n_points {
        // Random world point in front of the camera.
        let xw = 4.0 * rng.next_f64() - 2.0;
        let yw = 4.0 * rng.next_f64() - 2.0;
        let zw = 2.0 + 4.0 * rng.next_f64();
        let xh = [xw, yw, zw, 1.0];
        let dot = |r: usize| -> f64 { (0..4).map(|k| p[4 * r + k] * xh[k]).sum() };
        let w = dot(2);
        // Noisy pixel observation (0.5 px detector noise).
        let u = dot(0) / w + 0.5 * noise.standard(&mut rng);
        let v = dot(1) / w + 0.5 * noise.standard(&mut rng);
        // DLT rows (11 unknowns: p11..p33, p34 = 1 moved to rhs):
        //   [X Y Z 1 0 0 0 0 -uX -uY -uZ] p = u
        rows.extend_from_slice(&[xw, yw, zw, 1.0, 0.0, 0.0, 0.0, 0.0, -u * xw, -u * yw, -u * zw]);
        b.push(u);
        rows.extend_from_slice(&[0.0, 0.0, 0.0, 0.0, xw, yw, zw, 1.0, -v * xw, -v * yw, -v * zw]);
        b.push(v);
    }

    let m = b.len();
    let a = Matrix::from_vec(m, 11, rows).expect("DLT matrix");

    // Raw DLT systems are notoriously ill-conditioned (column scales differ
    // by ~1000x between the X/Y/Z terms and the -u*X terms), which stalls
    // any row-action method. Standard practice is data normalization; the
    // equivalent algebraic form is column equilibration: solve A D^-1 y = b,
    // then x = D^-1 y.
    let mut col_norms = vec![0.0f64; 11];
    for i in 0..m {
        for (j, cn) in col_norms.iter_mut().enumerate() {
            *cn += a[(i, j)] * a[(i, j)];
        }
    }
    for cn in col_norms.iter_mut() {
        *cn = cn.sqrt().max(1e-300);
    }
    let mut eq = Matrix::zeros(m, 11);
    for i in 0..m {
        for j in 0..11 {
            eq[(i, j)] = a[(i, j)] / col_norms[j];
        }
    }
    let mut sys = LinearSystem::new(eq, b, None, false);
    attach_least_squares(&mut sys, 1e-12, 50_000).expect("CGLS");
    println!("system: {m} x 11, inconsistent (pixel noise), column-equilibrated");

    let unscale = |y: &[f64]| -> Vec<f64> {
        y.iter().zip(&col_norms).map(|(v, cn)| v / cn).collect()
    };
    let opts = SolveOptions::default().with_fixed_iterations(200_000);
    let rk_r = RkSolver::new(3).solve(&sys, &opts);
    let opts_b = SolveOptions::default().with_fixed_iterations(200_000 / 11 / 8);
    let rkab_r = RkabSolver::new(3, 8, 11, 1.0).solve(&sys, &opts_b);
    let rk = unscale(&rk_r.x);
    let rkab = unscale(&rkab_r.x);
    let ls = unscale(sys.x_ls.as_ref().unwrap());

    let param_err = |x: &[f64]| -> f64 {
        x.iter()
            .zip(p.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    };
    println!("\n{:<22} {:>14} {:>14}", "method", "param error", "residual");
    println!("{:<22} {:>14.6} {:>14.4}", "RK (200k its)", param_err(&rk), sys.residual_norm(&rk_r.x));
    println!("{:<22} {:>14.6} {:>14.4}", "RKAB (q=8, bs=11)", param_err(&rkab), sys.residual_norm(&rkab_r.x));
    println!("{:<22} {:>14.6} {:>14.4}", "CGLS (x_LS)", param_err(&ls), sys.residual_norm(sys.x_ls.as_ref().unwrap()));

    println!("\nfirst row of P (true vs RKAB estimate):");
    for k in 0..4 {
        println!("  p1{}: {:>12.4} vs {:>12.4}", k + 1, p[k], rkab.get(k).copied().unwrap_or(0.0));
    }
}
