//! Quickstart: generate a dense overdetermined system and solve it with the
//! whole solver family, printing a small comparison table.
//!
//! Run: `cargo run --release --example quickstart`

use kaczmarz::data::DatasetBuilder;
use kaczmarz::report::{fmt_seconds, Table};
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

fn main() {
    // A paper-style consistent system: per-row gaussian entries, b = A x*.
    let (m, n) = (4000, 400);
    println!("generating {m} x {n} consistent dense system...");
    let sys = DatasetBuilder::new(m, n).seed(2024).consistent();

    let opts = SolveOptions::default().with_tolerance(1e-8);
    let solvers: Vec<Box<dyn Solver>> = vec![
        Box::new(CkSolver::new()),
        Box::new(RkSolver::new(7)),
        Box::new(RkaSolver::new(7, 8, 1.0)),
        Box::new(RkabSolver::new(7, 8, n, 1.0)),
    ];

    let mut t = Table::new(
        format!("Solving {m} x {n} to ||x - x*||^2 < 1e-8"),
        &["solver", "iterations", "rows used", "time", "final err^2"],
    );
    for s in solvers {
        let r = s.solve(&sys, &opts);
        t.row(vec![
            s.name().to_string(),
            r.iterations.to_string(),
            r.rows_used.to_string(),
            fmt_seconds(r.seconds),
            format!("{:.2e}", sys.error_sq(&r.x)),
        ]);
    }
    println!("{}", t.to_text());
    println!("(RKA/RKAB rows-used exceed RK's — the averaging costs information;");
    println!(" the paper's parallel win comes from amortizing communication, see");
    println!(" `kaczmarz experiment table2`.)");
}
