//! Distributed solve on the simulated cluster: partition a system too big
//! for "one machine", run Algorithm 4 (distributed RKAB) across ranks, and
//! break down where the time goes (compute vs Allreduce) under the paper's
//! two process placements.
//!
//! Run: `cargo run --release --example distributed_solve`

use kaczmarz::data::DatasetBuilder;
use kaczmarz::distributed::{DistRkab, Placement, SimCluster};
use kaczmarz::report::{fmt_seconds, Table};
use kaczmarz::solvers::SolveOptions;

fn main() {
    let (m, n) = (12_000, 600);
    println!("generating {m} x {n} consistent system, partitioning across ranks...");
    let sys = DatasetBuilder::new(m, n).seed(5).consistent();

    let mut t = Table::new(
        format!("Distributed RKAB ({m} x {n}, bs = n = {n})"),
        &["np", "placement", "iters", "max compute", "max comm", "sim total"],
    );
    for np in [2usize, 4, 8, 12] {
        for (label, placement) in
            [("24/node", Placement::full_node()), ("2/node", Placement::two_per_node())]
        {
            let cluster = SimCluster::new(np, placement);
            // Calibrate to tolerance, then a timed fixed-iteration run
            // (the paper's protocol).
            let cal = DistRkab::new(3, n, 1.0).solve(&sys, &SolveOptions::default(), &cluster);
            let timed = DistRkab::new(3, n, 1.0).solve(
                &sys,
                &SolveOptions::default().with_fixed_iterations(cal.iterations.max(1)),
                &cluster,
            );
            let max_comp = timed
                .rank_stats
                .iter()
                .map(|s| s.adjusted_compute_seconds)
                .fold(0.0, f64::max);
            let max_comm =
                timed.rank_stats.iter().map(|s| s.comm_seconds).fold(0.0, f64::max);
            t.row(vec![
                np.to_string(),
                label.to_string(),
                cal.iterations.to_string(),
                fmt_seconds(max_comp),
                fmt_seconds(max_comm),
                fmt_seconds(timed.sim_seconds),
            ]);
        }
    }
    println!("{}", t.to_text());
    println!("note: ranks are simulated (threads with private memory + modeled");
    println!("alpha-beta interconnect); see DESIGN.md §3 for the substitution.");
}
