//! Batch-solve serving: many requests per pool dispatch.
//!
//! The serving shape the ROADMAP's north star asks for, in miniature. One
//! "model" system stays resident; requests arrive as right-hand sides; a
//! [`BatchSolver`] fans them across the persistent worker pool (zero thread
//! spawns after warm-up) and returns per-request reports. A [`SolveQueue`]
//! then shows the multi-tenant shape: independent systems with independent
//! stopping rules drained by one dispatch.
//!
//! Run with: `cargo run --release --example batch_serving`

use kaczmarz::batch::{BatchJob, BatchSolver, SolveQueue};
use kaczmarz::data::DatasetBuilder;
use kaczmarz::linalg::gemv;
use kaczmarz::metrics::Stopwatch;
use kaczmarz::report::{fmt_seconds, Table};
use kaczmarz::rng::Mt19937;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::SolveOptions;

fn main() {
    // The resident system: row norms and sampling state are prepared once.
    let (m, n) = (2000, 200);
    let system = DatasetBuilder::new(m, n).seed(1).consistent();
    println!("resident system: {m} x {n} (consistent by construction)\n");

    // A burst of requests b_j = A x_j with known x_j (so the solver can
    // stop on error); a real deployment would use fixed-iteration budgets.
    let n_requests = 24;
    let mut rng = Mt19937::new(9);
    let jobs: Vec<BatchJob> = (0..n_requests)
        .map(|_| {
            let x: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
            BatchJob::new(gemv(&system.a, &x).unwrap()).with_reference(x)
        })
        .collect();

    let opts = SolveOptions::default().with_fixed_iterations(3000);
    let batch = BatchSolver::new(&system, RkSolver::new(7));
    // Warm-up with the full batch: spawns (and parks) every lane's worker
    // before the timed run, so request N+1 pays zero thread spawns.
    batch.solve_many(&jobs, &opts).unwrap();

    let sw = Stopwatch::start();
    let reports = batch.solve_many(&jobs, &opts).unwrap();
    let elapsed = sw.seconds();

    let mut t = Table::new(
        format!("BatchSolver: {n_requests} rhs in {}", fmt_seconds(elapsed)),
        &["job", "solver", "iterations", "residual"],
    );
    for r in reports.iter().take(5) {
        t.row(vec![
            r.job.to_string(),
            r.solver.to_string(),
            r.result.iterations.to_string(),
            format!("{:.2e}", r.residual_norm),
        ]);
    }
    println!("{}", t.to_text());
    println!(
        "throughput: {:.0} solves/s ({} per request)\n",
        n_requests as f64 / elapsed,
        fmt_seconds(elapsed / n_requests as f64)
    );

    // Live telemetry: watch lanes converge *while* the batch runs. Each
    // job carries its own ProgressSink (here a callback printing one line
    // per checkpoint; a service would use ProgressSink::bounded and poll
    // the receivers). Sinks stream from the solve's existing checkpoints —
    // no new GEMVs — and never perturb the solve (results stay bitwise
    // identical to unwatched runs).
    println!("live per-lane progress (4 watched jobs, history every 1000 iters):");
    let watched: Vec<BatchJob> = jobs
        .iter()
        .take(4)
        .enumerate()
        .map(|(j, job)| {
            job.clone().with_progress(kaczmarz::metrics::ProgressSink::callback(
                move |s| {
                    println!(
                        "  [job {j}] k={:<5} ||Ax-b||={:.3e} t={:.1?}",
                        s.k, s.residual, s.elapsed
                    );
                },
            ))
        })
        .collect();
    let watch_opts = SolveOptions::default().with_fixed_iterations(3000).with_history_step(1000);
    batch.solve_many(&watched, &watch_opts).unwrap();
    println!();

    // Multi-tenant queue: mixed systems and stopping rules, one dispatch.
    let mut queue = SolveQueue::new();
    queue.push(DatasetBuilder::new(400, 16).seed(2).consistent(), SolveOptions::default());
    queue.push(
        DatasetBuilder::new(300, 10).seed(3).inconsistent(),
        SolveOptions::default().with_fixed_iterations(2000),
    );
    queue.push(DatasetBuilder::new(250, 8).seed(4).consistent(), SolveOptions::default());
    // The serving shape proper: a system whose solution nobody knows (no
    // reference attached), stopped on the residual — `converged = true`
    // below *certifies* ‖Ax - b‖² < 1e-6, solved in place with zero clones.
    let unknown = DatasetBuilder::new(350, 12).seed(5).consistent();
    queue.push(
        kaczmarz::data::LinearSystem::new(unknown.a.clone(), unknown.b.clone(), None, true),
        SolveOptions::default().with_residual_stopping(1e-6, 32),
    );

    let reports = queue.run(&RkSolver::new(11)).unwrap();
    let mut t = Table::new(
        "SolveQueue: mixed jobs, per-job reports",
        &["job", "converged", "iterations", "residual"],
    );
    for r in &reports {
        t.row(vec![
            r.job.to_string(),
            r.result.converged.to_string(),
            r.result.iterations.to_string(),
            format!("{:.2e}", r.residual_norm),
        ]);
    }
    println!("{}", t.to_text());
    println!(
        "notes: job 1 is inconsistent — its fixed budget measures nothing, so it\n\
         reports converged=false and its residual floor is the honest answer;\n\
         job 3 has no reference solution at all — residual stopping certified it."
    );
}
