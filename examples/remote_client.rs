//! Remote serving: a wire client against an in-process `WireServer`.
//!
//! The network serving story end to end, self-contained in one process:
//! boot the framed-TCP server on an OS-assigned port (exactly what
//! `kaczmarz serve` does), then talk to it **only through TCP** with the
//! [`serve::client`](kaczmarz::serve::client) helpers — the same calls
//! `kaczmarz submit` makes from another machine. Three exchanges:
//!
//! 1. a normal job, streaming mid-solve `SAMPLE` frames to completion;
//! 2. a job with a 1 ms deadline, refused *typed* (`deadline`) while a
//!    sibling job right behind it still completes — lanes never poison;
//! 3. an endless job cancelled from a second connection mid-solve.
//!
//! Run with: `cargo run --release --example remote_client`

use kaczmarz::data::DatasetBuilder;
use kaczmarz::serve::wire::SubmitFrame;
use kaczmarz::serve::{
    client, FrontEndConfig, RemoteOutcome, SolveFrontEnd, SystemRegistry, WireServer,
};
use std::sync::Arc;

fn main() {
    // Server side: two resident systems behind the LRU registry, two
    // admission lanes, a bounded queue. Port 0 = let the OS pick.
    let registry = Arc::new(SystemRegistry::new(256 << 20));
    registry.insert("demo", DatasetBuilder::new(1200, 80).seed(1).consistent());
    registry.insert("tiny", DatasetBuilder::new(200, 12).seed(2).consistent());
    let front = Arc::new(SolveFrontEnd::new(
        Arc::clone(&registry),
        FrontEndConfig { lanes: 2, max_pending: 8 },
    ));
    let server = WireServer::bind("127.0.0.1:0", front).expect("bind").spawn().expect("spawn");
    let addr = server.addr();
    println!("server up on {addr} ({} resident systems)\n", registry.len());

    client::ping(addr).expect("server answers PING");

    // 1. Normal job: stream it to completion. Every SAMPLE line rides an
    // existing solve checkpoint — telemetry costs zero extra GEMVs.
    println!("== streaming solve of 'demo'");
    let mut frame = SubmitFrame::new("demo");
    frame.tol = 1e-10;
    frame.check = 64;
    let (id, outcome) = client::submit_streaming(addr, &frame, |id, k, residual, ms| {
        println!("  job {id}: k={k:<6} ||Ax-b||={residual:.3e} t={ms}ms");
    })
    .expect("transport");
    match outcome {
        RemoteOutcome::Done { iterations, converged, residual, queue_wait_ms, dropped } => {
            println!(
                "  job {id} done: {iterations} iterations, converged={converged}, \
                 residual={residual:.3e}, queue_wait={queue_wait_ms}ms, dropped={dropped}\n"
            );
        }
        other => panic!("expected Done, got {other:?}"),
    }

    // 2. Deadline: the budget starts at submit and is checked at the same
    // solve checkpoints — the failure is a typed wire error, not a hang.
    println!("== 1 ms deadline on an unsatisfiable tolerance");
    let mut doomed = SubmitFrame::new("demo");
    doomed.tol = 0.0;
    doomed.check = 64;
    doomed.max_iterations = Some(usize::MAX / 2);
    doomed.deadline_ms = Some(1);
    match client::submit_streaming(addr, &doomed, |_, _, _, _| {}).expect("transport") {
        (id, RemoteOutcome::Failed { kind, msg }) => {
            println!("  job {id} refused typed: kind={} msg={msg}", kind.token())
        }
        (_, other) => panic!("expected a typed deadline failure, got {other:?}"),
    }
    // The lane is healthy: a sibling submitted right after completes.
    let (_, sibling) = client::submit_streaming(addr, &SubmitFrame::new("tiny"), |_, _, _, _| {})
        .expect("transport");
    println!("  sibling on 'tiny' right after: {sibling:?}\n");

    // 3. Cancel mid-solve from a second connection: the callback gets the
    // job id with its first sample, exactly so it can act on the job.
    println!("== cancelling an endless job from a second connection");
    let mut endless = SubmitFrame::new("demo");
    endless.tol = 0.0;
    endless.check = 64;
    endless.max_iterations = Some(usize::MAX / 2);
    let (id, outcome) = client::submit_streaming(addr, &endless, |id, _, _, _| {
        // First sample proves the solve is running; repeat cancels are no-ops.
        let _ = client::cancel(addr, id);
    })
    .expect("transport");
    match outcome {
        RemoteOutcome::Failed { kind, .. } => {
            println!("  job {id} ended typed: kind={}", kind.token())
        }
        other => panic!("expected cancelled, got {other:?}"),
    }

    // Server-side accounting survives it all.
    let stats = server.front().stats();
    println!(
        "\nfront-end stats: submitted={} completed={} cancelled={} deadline_missed={} \
         rejected={}",
        stats.submitted, stats.completed, stats.cancelled, stats.deadline_missed, stats.rejected
    );
    server.shutdown();
}
