//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! Pipeline (the paper's full method, miniaturized):
//!   1. generate the paper's §3.1 data sets (consistent + inconsistent);
//!   2. CGLS computes the least-squares reference;
//!   3. sequential baselines: CK / RK;
//!   4. the paper's contribution: RKA and RKAB, shared-memory (threaded
//!      engine) and distributed (simulated cluster);
//!   5. **PJRT path**: RKAB whose inner update executes the AOT-compiled
//!      JAX/Pallas kernel (`artifacts/rkab_round_*.hlo.txt`) through the
//!      xla crate — validated against the native solver in-run;
//!   6. the Table-2 headline: RKAB(a=1) vs RKA(a=1) vs RKA(a*) + a* cost;
//!   7. writes results/e2e_report.md (EXPERIMENTS.md records a run).
//!
//! Run: `make artifacts && cargo run --release --example paper_pipeline`

use kaczmarz::coordinator::{calibrate_iterations, CostModel};
use kaczmarz::data::DatasetBuilder;
use kaczmarz::distributed::{DistRkab, Placement, SimCluster};
use kaczmarz::parallel::{AveragingStrategy, ParallelRka, ParallelRkab};
use kaczmarz::report::{fmt_seconds, Report, Table};
use kaczmarz::runtime::{default_artifacts_dir, PjrtRkabSolver};
use kaczmarz::solvers::alpha::full_matrix_alpha;
use kaczmarz::solvers::cgls::attach_least_squares;
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

fn main() {
    let mut report = Report::new();
    report.text("# End-to-end pipeline report\n");
    let t0 = std::time::Instant::now();

    // ---- 1. Data sets (n = 256 so the PJRT artifact shape matches). ----
    let (m, n) = (8_000usize, 256usize);
    println!("[1/7] generating {m} x {n} consistent + inconsistent systems...");
    let sys = DatasetBuilder::new(m, n).seed(2024).consistent();
    let mut noisy = DatasetBuilder::new(m, n).seed(2024).inconsistent();

    // ---- 2. CGLS reference. ----
    println!("[2/7] CGLS least-squares reference...");
    attach_least_squares(&mut noisy, 1e-12, 100_000).expect("CGLS");
    report.text(format!(
        "Workload: {m} x {n} dense (paper §3.1 generator); LS residual = {:.4e}.\n",
        noisy.residual_norm(noisy.x_ls.as_ref().unwrap())
    ));

    // ---- 3. Sequential baselines. ----
    println!("[3/7] sequential baselines (CK, RK)...");
    let opts = SolveOptions::default();
    let ck = CkSolver::new().solve(&sys, &opts);
    let rk = RkSolver::new(7).solve(&sys, &opts);
    let mut t = Table::new("Sequential baselines", &["solver", "iterations", "time", "err^2"]);
    for (name, r) in [("CK", &ck), ("RK", &rk)] {
        t.row(vec![
            name.into(),
            r.iterations.to_string(),
            fmt_seconds(r.seconds),
            format!("{:.1e}", sys.error_sq(&r.x)),
        ]);
    }
    report.table(&t);

    // ---- 4. The paper's parallel methods (real threaded engine). ----
    println!("[4/7] threaded RKA / RKAB (q = 4)...");
    let q = 4usize;
    let rka = ParallelRka::new(7, q, 1.0)
        .with_strategy(AveragingStrategy::Critical)
        .solve(&sys, &opts);
    let rkab = ParallelRkab::new(7, q, n, 1.0).solve(&sys, &opts);
    let cluster = SimCluster::new(q, Placement::two_per_node());
    let dist = DistRkab::new(7, n, 1.0).solve(&sys, &opts, &cluster);
    let mut t = Table::new(
        "Parallel engines (q = 4)",
        &["engine", "iterations", "rows used", "err^2", "note"],
    );
    t.row(vec![
        "RKA shared (critical)".into(),
        rka.iterations.to_string(),
        rka.rows_used.to_string(),
        format!("{:.1e}", sys.error_sq(&rka.x)),
        "Algorithm 1".into(),
    ]);
    t.row(vec![
        "RKAB shared".into(),
        rkab.iterations.to_string(),
        rkab.rows_used.to_string(),
        format!("{:.1e}", sys.error_sq(&rkab.x)),
        "Algorithm 3, bs = n".into(),
    ]);
    t.row(vec![
        "RKAB distributed (sim)".into(),
        dist.iterations.to_string(),
        dist.rows_used.to_string(),
        format!("{:.1e}", sys.error_sq(&dist.x)),
        format!("sim time {}", fmt_seconds(dist.sim_seconds)),
    ]);
    report.table(&t);

    // ---- 5. PJRT path: compiled Pallas kernel on the hot loop. ----
    println!("[5/7] PJRT path (AOT Pallas kernel via xla crate)...");
    let dir = default_artifacts_dir();
    let (bs_pjrt, iters_check) = (64usize, 30usize);
    let pjrt_row = match PjrtRkabSolver::new(&dir, 9, 4, bs_pjrt, n, 1.0) {
        Ok(solver) => {
            let fixed = SolveOptions::default().with_fixed_iterations(iters_check);
            let got = solver.solve(&sys, &fixed).expect("PJRT solve");
            let native = RkabSolver::new(9, 4, bs_pjrt, 1.0).solve(&sys, &fixed);
            let drift: f64 = got
                .x
                .iter()
                .zip(&native.x)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            let full = solver.solve(&sys, &opts).expect("PJRT solve");
            vec![
                "RKAB-pjrt (q=4)".to_string(),
                full.iterations.to_string(),
                format!("{:.1e}", sys.error_sq(&full.x)),
                format!("drift vs native {:.1e} over {iters_check} its", drift),
            ]
        }
        Err(e) => vec!["RKAB-pjrt".into(), "-".into(), "-".into(), format!("SKIPPED: {e}")],
    };
    let mut t = Table::new(
        "Three-layer composition (L3 rust -> PJRT -> L2 jax -> L1 pallas)",
        &["engine", "iterations", "err^2", "validation"],
    );
    t.row(pjrt_row);
    report.table(&t);

    // ---- 6. Headline metric: the Table-2 comparison. ----
    println!("[6/7] headline: RKAB vs RKA vs alpha* cost (modeled times)...");
    let model = CostModel::calibrate(&sys);
    let rk_cal = calibrate_iterations(RkSolver::new, &sys, &opts, 3)
        .expect("RK converges on consistent systems");
    let rk_time = rk_cal.mean_iterations * model.rk_iteration();
    let mut t = Table::new(
        format!("Headline (q = 8, bs = n; sequential RK = {})", fmt_seconds(rk_time)),
        &["method", "iterations", "modeled time", "+ alpha* cost"],
    );
    let q = 8usize;
    let rkab_cal = calibrate_iterations(|s| RkabSolver::new(s, q, n, 1.0), &sys, &opts, 3)
        .expect("RKAB(a=1) converges on consistent systems");
    let rkab_time = rkab_cal.mean_iterations * model.rkab_iteration(q, n);
    let rka1_cal = calibrate_iterations(|s| RkaSolver::new(s, q, 1.0), &sys, &opts, 3)
        .expect("RKA(a=1) converges on consistent systems");
    let rka1_time = rka1_cal.mean_iterations * model.rka_iteration(q, AveragingStrategy::Critical);
    let (astar, astar_cost) = full_matrix_alpha(&sys, q).expect("alpha*");
    let rkao_cal = calibrate_iterations(|s| RkaSolver::new(s, q, astar), &sys, &opts, 3)
        .expect("RKA(a*) converges on consistent systems");
    let rkao_time = rkao_cal.mean_iterations * model.rka_iteration(q, AveragingStrategy::Critical);
    t.row(vec![
        "RKAB (a=1)".into(),
        rkab_cal.iterations().to_string(),
        fmt_seconds(rkab_time),
        fmt_seconds(rkab_time),
    ]);
    t.row(vec![
        "RKA (a=1)".into(),
        rka1_cal.iterations().to_string(),
        fmt_seconds(rka1_time),
        fmt_seconds(rka1_time),
    ]);
    t.row(vec![
        format!("RKA (a* = {astar:.3})"),
        rkao_cal.iterations().to_string(),
        fmt_seconds(rkao_time),
        fmt_seconds(rkao_time + astar_cost),
    ]);
    report.table(&t);
    let win = rkab_time < rka1_time && rkab_time < rkao_time + astar_cost;
    report.text(format!(
        "**Headline check (paper Table 2 shape): RKAB(a=1) beats RKA(a=1) and \
         beats RKA(a*) once the a* cost is charged — {}.**\n",
        if win { "REPRODUCED" } else { "NOT reproduced at this scale" }
    ));

    // ---- 7. Horizon check on the inconsistent system. ----
    println!("[7/7] convergence horizon on the inconsistent system...");
    let h_opts = SolveOptions::default().with_fixed_iterations(20_000).with_history_step(500);
    let h1 = RkaSolver::new(2, 1, 1.0).solve(&noisy, &h_opts);
    let h20 = RkaSolver::new(2, 20, 1.0).solve(&noisy, &h_opts);
    let hb = RkabSolver::new(2, 20, n, 1.0)
        .solve(&noisy, &SolveOptions::default().with_fixed_iterations(50).with_history_step(2));
    let mut t = Table::new(
        "Convergence horizon ||x - x_LS|| (tail mean)",
        &["method", "q", "horizon"],
    );
    t.row(vec!["RK".into(), "1".into(), format!("{:.4e}", h1.history.tail_error(5).unwrap())]);
    t.row(vec!["RKA".into(), "20".into(), format!("{:.4e}", h20.history.tail_error(5).unwrap())]);
    t.row(vec!["RKAB (bs=n)".into(), "20".into(), format!("{:.4e}", hb.history.tail_error(5).unwrap())]);
    report.table(&t);

    report.text(format!("\nTotal pipeline wall time: {:.1} s.\n", t0.elapsed().as_secs_f64()));
    let path = report.write(std::path::Path::new("results"), "e2e_report").expect("write");
    println!("\n{}", report.to_markdown());
    println!("wrote {}", path.display());
}
