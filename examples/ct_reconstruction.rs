//! CT-scan image reconstruction — the paper's motivating application (§1).
//!
//! A parallel-beam computed-tomography setup reduced to a linear system:
//! the image is an N x N grid of attenuation coefficients, each measurement
//! is a ray whose row holds the intersection lengths with the pixels it
//! crosses, and b is the measured line integral (plus detector noise). With
//! enough angles the system is overdetermined and inconsistent — exactly the
//! regime where the paper recommends RKA/RKAB to shrink the convergence
//! horizon rather than chase the (noise-fitting) least-squares solution.
//!
//! Run: `cargo run --release --example ct_reconstruction`

use kaczmarz::data::LinearSystem;
use kaczmarz::linalg::Matrix;
use kaczmarz::rng::{Mt19937, NormalSampler};
use kaczmarz::solvers::cgls::attach_least_squares;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

/// Shepp-Logan-ish phantom: a couple of ellipses on an N x N grid.
fn phantom(n_px: usize) -> Vec<f64> {
    let mut img = vec![0.0; n_px * n_px];
    let c = (n_px as f64 - 1.0) / 2.0;
    for i in 0..n_px {
        for j in 0..n_px {
            let x = (j as f64 - c) / c;
            let y = (i as f64 - c) / c;
            // Outer skull.
            if x * x / 0.9 + y * y / 0.95 < 1.0 {
                img[i * n_px + j] = 1.0;
            }
            // Inner tissue.
            if x * x / 0.55 + y * y / 0.65 < 1.0 {
                img[i * n_px + j] = 0.4;
            }
            // Two lesions.
            if (x - 0.3) * (x - 0.3) + (y - 0.2) * (y - 0.2) < 0.02 {
                img[i * n_px + j] = 1.8;
            }
            if (x + 0.25) * (x + 0.25) + (y + 0.3) * (y + 0.3) < 0.015 {
                img[i * n_px + j] = 0.05;
            }
        }
    }
    img
}

/// Trace a ray through the pixel grid with a dense siddon-like sampling:
/// returns the row of intersection weights.
fn trace_ray(n_px: usize, angle: f64, offset: f64) -> Vec<f64> {
    let mut row = vec![0.0; n_px * n_px];
    let c = (n_px as f64 - 1.0) / 2.0;
    let (s, co) = angle.sin_cos();
    // Ray: point p(t) = center + offset*normal + t*direction.
    let steps = 4 * n_px;
    let step = n_px as f64 * 1.5 / steps as f64;
    for k in 0..steps {
        let t = (k as f64 - steps as f64 / 2.0) * step;
        let x = c + offset * (-s) + t * co;
        let y = c + offset * co + t * s;
        let (i, j) = (y.round() as isize, x.round() as isize);
        if i >= 0 && j >= 0 && (i as usize) < n_px && (j as usize) < n_px {
            row[i as usize * n_px + j as usize] += step;
        }
    }
    row
}

fn main() {
    let n_px = 24; // 576 unknowns
    let n = n_px * n_px;
    let angles = 60;
    let offsets = 20; // m = 1200 rays: overdetermined ~2x
    println!("CT setup: {n_px}x{n_px} image ({n} unknowns), {angles} angles x {offsets} offsets");

    let img = phantom(n_px);
    let mut rng = Mt19937::new(7);
    let mut noise = NormalSampler::new();

    let mut rows = Vec::new();
    let mut b = Vec::new();
    for a in 0..angles {
        let angle = std::f64::consts::PI * a as f64 / angles as f64;
        for o in 0..offsets {
            let offset = (o as f64 - offsets as f64 / 2.0) * (n_px as f64 / offsets as f64);
            let row = trace_ray(n_px, angle, offset);
            let integral: f64 = row.iter().zip(&img).map(|(w, v)| w * v).sum();
            // Skip rays that miss the object entirely (zero rows break eq. 4).
            if row.iter().any(|&w| w > 0.0) {
                b.push(integral + 0.05 * noise.standard(&mut rng)); // detector noise
                rows.push(row);
            }
        }
    }
    let m = rows.len();
    let flat: Vec<f64> = rows.into_iter().flatten().collect();
    let a = Matrix::from_vec(m, n, flat).expect("ray matrix");
    let mut sys = LinearSystem::new(a, b, Some(img.clone()), false);
    attach_least_squares(&mut sys, 1e-10, 20_000).expect("CGLS");
    println!("system: {m} x {n} (inconsistent; detector noise sigma = 0.05)");

    // Reconstruct with RKA (q=16) and RKAB (q=16, bs=n) — the paper's §3.5
    // recipe for regularized reconstruction.
    let opts = SolveOptions::default().with_fixed_iterations(40_000).with_history_step(4_000);
    let rka = RkaSolver::new(3, 16, 1.0).solve(&sys, &opts);
    let opts_b =
        SolveOptions::default().with_fixed_iterations(40_000 / n).with_history_step(4);
    let rkab = RkabSolver::new(3, 16, n, 1.0).solve(&sys, &opts_b);

    let rel = |x: &[f64]| {
        let num: f64 = x.iter().zip(&img).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let den: f64 = img.iter().map(|v| v * v).sum::<f64>().sqrt();
        num / den
    };
    println!("RKA  (q=16):  relative image error {:.4}, residual {:.4}", rel(&rka.x), sys.residual_norm(&rka.x));
    println!("RKAB (q=16):  relative image error {:.4}, residual {:.4}", rel(&rkab.x), sys.residual_norm(&rkab.x));
    println!("LS solution:  relative image error {:.4} (fits the noise!)", rel(sys.x_ls.as_ref().unwrap()));

    // Coarse ASCII render of the reconstruction.
    println!("\nreconstruction (RKAB):");
    let shades = [' ', '.', ':', '+', '#', '@'];
    for i in 0..n_px {
        let line: String = (0..n_px)
            .map(|j| {
                let v = rkab.x[i * n_px + j].clamp(0.0, 2.0) / 2.0;
                shades[(v * (shades.len() - 1) as f64).round() as usize]
            })
            .collect();
        println!("  {line}");
    }
}
