"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/ for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``;
the Rust side unwraps with ``to_tuple1()``.

Run via ``make artifacts`` (no-op when artifacts are newer than sources):

    cd python && python -m compile.aot --outdir ../artifacts

Emits one ``<name>.hlo.txt`` per (model, shape) plus ``manifest.txt`` with
lines ``<name> <kind> <q> <bs> <n> <file>`` the Rust runtime indexes.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from compile.model import rka_step_model, rkab_block_model, rkab_round_model  # noqa: E402

# Shape catalogue. VMEM discipline: bs*n <= 2M doubles (16 MB); the rust
# PJRT solver picks the artifact matching its (q, bs, n) configuration.
RKA_STEP_SHAPES = [(2, 256), (4, 256), (8, 256), (4, 512), (8, 512), (16, 512), (8, 1000)]
RKAB_BLOCK_SHAPES = [(64, 256), (256, 256), (128, 512), (512, 512), (500, 500), (1000, 1000)]
RKAB_ROUND_SHAPES = [(2, 64, 256), (4, 64, 256), (4, 256, 256), (2, 500, 500), (4, 500, 500)]

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def lower_all():
    """Yield (name, kind, q, bs, n, hlo_text) for the full catalogue."""
    for q, n in RKA_STEP_SHAPES:
        lowered = jax.jit(rka_step_model).lower(
            spec(q, n), spec(q), spec(q), spec(n), spec(1)
        )
        yield (f"rka_step_q{q}_n{n}", "rka_step", q, 1, n, to_hlo_text(lowered))
    for bs, n in RKAB_BLOCK_SHAPES:
        lowered = jax.jit(rkab_block_model).lower(
            spec(bs, n), spec(bs), spec(bs), spec(n), spec(1)
        )
        yield (f"rkab_block_bs{bs}_n{n}", "rkab_block", 1, bs, n, to_hlo_text(lowered))
    for q, bs, n in RKAB_ROUND_SHAPES:
        lowered = jax.jit(rkab_round_model).lower(
            spec(q, bs, n), spec(q, bs), spec(q, bs), spec(n), spec(1)
        )
        yield (f"rkab_round_q{q}_bs{bs}_n{n}", "rkab_round", q, bs, n, to_hlo_text(lowered))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest_lines = []
    for name, kind, q, bs, n, text in lower_all():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {kind} {q} {bs} {n} {fname}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
