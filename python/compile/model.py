"""L2: the paper's update graphs as JAX functions calling the L1 kernels.

Three exported computations, all AOT-lowered to HLO text by ``aot.py``:

- ``rka_step_model``   — eq. (7), one RKA iteration given the q sampled rows;
- ``rkab_block_model`` — eq. (8), one worker's in-block sweep;
- ``rkab_round_model`` — eqs. (8)+(9), a full RKAB iteration: vmap of the
  block-sweep kernel over the q workers' blocks, then the eq. (9) average.

The Rust coordinator (L3) owns row *sampling* and the outer iteration loop —
randomness stays out of the compiled graphs so one artifact serves every
seed. Doubles (f64) throughout to match the Rust solvers bit-for-bit modulo
reassociation.
"""

import jax
import jax.numpy as jnp

from compile.kernels.rka_step import rka_step
from compile.kernels.rkab_block import rkab_block

jax.config.update("jax_enable_x64", True)


def rka_step_model(a_rows, b_rows, inv_norms, x, alpha_over_q):
    """One RKA iteration (eq. 7). Returns a 1-tuple for the AOT contract."""
    return (rka_step(a_rows, b_rows, inv_norms, x, alpha_over_q),)


def rkab_block_model(a_block, b_block, inv_norms, x, alpha):
    """One worker's RKAB block sweep (eq. 8)."""
    return (rkab_block(a_block, b_block, inv_norms, x, alpha),)


def rkab_round_model(a_blocks, b_blocks, inv_norms, x, alpha):
    """One full RKAB iteration (eqs. 8+9).

    Args:
      a_blocks: (q, bs, n); b_blocks, inv_norms: (q, bs); x: (n,); alpha: (1,).
    Returns:
      1-tuple of (n,): the averaged next iterate.
    """
    sweep = jax.vmap(lambda a, b, w: rkab_block(a, b, w, x, alpha))
    v = sweep(a_blocks, b_blocks, inv_norms)  # (q, n)
    return (jnp.mean(v, axis=0),)
