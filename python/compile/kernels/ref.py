"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest) and the
direct transcription of the paper's equations:

- ``rka_step_ref``    — eq. (7): one averaged RKA update over the q sampled
  rows tau_k;
- ``rkab_block_ref``  — eq. (8): one worker's sequential in-block Kaczmarz
  sweep;
- ``rkab_round_ref``  — eqs. (8)+(9): all q workers' sweeps averaged.
"""

import jax
import jax.numpy as jnp


def rka_step_ref(a_rows, b_rows, inv_norms, x, alpha_over_q):
    """Eq. (7): x + (alpha/q) * sum_i (b_i - <A_i, x>) / ||A_i||^2 * A_i.

    Args:
      a_rows:      (q, n) the sampled rows.
      b_rows:      (q,)   their b entries.
      inv_norms:   (q,)   1 / ||A^(i)||^2.
      x:           (n,)   current iterate.
      alpha_over_q: scalar weight (alpha / q premultiplied), shape (1,).
    Returns:
      (n,) next iterate.
    """
    residuals = b_rows - a_rows @ x                   # (q,)
    scales = alpha_over_q[0] * residuals * inv_norms  # (q,)
    return x + a_rows.T @ scales


def rkab_block_ref(a_block, b_block, inv_norms, x, alpha):
    """Eq. (8): bs sequential Kaczmarz projections on a private iterate v.

    Args:
      a_block:   (bs, n) the block's rows, in processing order.
      b_block:   (bs,)   their b entries.
      inv_norms: (bs,)   1 / ||A^(i)||^2.
      x:         (n,)    block start iterate (v^(0) = x).
      alpha:     (1,)    relaxation weight.
    Returns:
      (n,) v after the sweep.
    """

    a_block = jnp.asarray(a_block)
    b_block = jnp.asarray(b_block)
    inv_norms = jnp.asarray(inv_norms)

    def body(j, v):
        row = a_block[j]
        scale = alpha[0] * (b_block[j] - jnp.dot(row, v)) * inv_norms[j]
        return v + scale * row

    return jax.lax.fori_loop(0, a_block.shape[0], body, jnp.asarray(x))


def rkab_round_ref(a_blocks, b_blocks, inv_norms, x, alpha):
    """Eqs. (8)+(9): average of q workers' block sweeps.

    Args:
      a_blocks:  (q, bs, n); b_blocks / inv_norms: (q, bs); x: (n,);
      alpha: (1,).
    Returns:
      (n,) x^(k+1) = (1/q) sum_gamma v_gamma.
    """
    sweep = jax.vmap(lambda a, b, w: rkab_block_ref(a, b, w, x, alpha))
    return jnp.mean(sweep(a_blocks, b_blocks, inv_norms), axis=0)
