"""L1 Pallas kernel: one averaged RKA update (paper eq. 7).

The update is two MXU-shaped contractions around an elementwise scale:

    r      = b_tau - A_tau @ x          (q, n) x (n,)  -> (q,)
    s      = (alpha/q) * r / ||A_i||^2  elementwise    -> (q,)
    x_next = x + A_tau^T @ s            (n, q) x (q,)  -> (n,)

TPU adaptation (DESIGN.md §Hardware-Adaptation): `A_tau` is the only large
operand; with BlockSpec tiling over n it streams HBM->VMEM once and feeds
both contractions, while `x`, `b`, and the scales stay VMEM-resident. Under
`interpret=True` (required on CPU — real TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot run) the grid collapses to one
program, which is what we AOT-export.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rka_step_kernel(a_ref, b_ref, inv_norms_ref, x_ref, alpha_ref, o_ref):
    """Body: everything VMEM-resident (q x n blocks are small by design)."""
    x = x_ref[...]
    a = a_ref[...]
    residuals = b_ref[...] - a @ x
    scales = alpha_ref[0] * residuals * inv_norms_ref[...]
    o_ref[...] = x + a.T @ scales


@functools.partial(jax.jit, static_argnames=())
def rka_step(a_rows, b_rows, inv_norms, x, alpha_over_q):
    """Pallas-backed eq. (7) update. Shapes: (q,n), (q,), (q,), (n,), (1,)."""
    q, n = a_rows.shape
    assert b_rows.shape == (q,) and inv_norms.shape == (q,)
    assert x.shape == (n,) and alpha_over_q.shape == (1,)
    return pl.pallas_call(
        _rka_step_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(a_rows, b_rows, inv_norms, x, alpha_over_q)


def vmem_estimate_bytes(q, n, dtype_bytes=8):
    """VMEM footprint of one program instance (DESIGN.md §Perf).

    A_tau dominates: (q*n + 2*q + 2*n + 1) * dtype_bytes, plus the (n,)
    output accumulator.
    """
    return (q * n + 2 * q + 3 * n + 1) * dtype_bytes
