"""L1 Pallas kernel: one worker's sequential RKAB block sweep (paper eq. 8).

The sweep is *intrinsically sequential over rows* — projection j uses the
iterate produced by projection j-1 — so the parallelism lives across workers
(handled by L2/L3), not inside the block. The kernel therefore keeps the
whole (bs, n) block plus the running iterate `v` VMEM-resident and walks the
rows with an in-kernel `fori_loop`:

    v^(0) = x
    for j in 0..bs:  v += alpha * (b_j - <A_j, v>) / ||A_j||^2 * A_j

TPU adaptation (DESIGN.md §Hardware-Adaptation): this is the TPU analogue of
the paper's per-thread cache-resident submatrix — the block is staged
HBM->VMEM once (bs*n*8 bytes must fit the ~16 MB VMEM budget; the AOT shapes
respect bs*n <= 2M doubles), each dot runs on the VPU/MXU, and only `v`
(n doubles) is live across loop steps. Under `interpret=True` it lowers to
plain HLO (a while-loop of dot/axpy) the CPU PJRT client executes directly.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rkab_block_kernel(a_ref, b_ref, inv_norms_ref, x_ref, alpha_ref, o_ref):
    """Body: sequential fori_loop over the block's rows."""
    a = a_ref[...]
    b = b_ref[...]
    inv_norms = inv_norms_ref[...]
    alpha = alpha_ref[0]
    bs = a.shape[0]

    def body(j, v):
        row = a[j]
        scale = alpha * (b[j] - jnp.dot(row, v)) * inv_norms[j]
        return v + scale * row

    o_ref[...] = jax.lax.fori_loop(0, bs, body, x_ref[...])


def rkab_block(a_block, b_block, inv_norms, x, alpha):
    """Pallas-backed eq. (8) sweep. Shapes: (bs,n), (bs,), (bs,), (n,), (1,)."""
    bs, n = a_block.shape
    assert b_block.shape == (bs,) and inv_norms.shape == (bs,)
    assert x.shape == (n,) and alpha.shape == (1,)
    return pl.pallas_call(
        _rkab_block_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(a_block, b_block, inv_norms, x, alpha)


def vmem_estimate_bytes(bs, n, dtype_bytes=8):
    """VMEM footprint of one program instance (DESIGN.md §Perf)."""
    return (bs * n + 2 * bs + 3 * n + 1) * dtype_bytes
