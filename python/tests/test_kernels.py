"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis-style sweeps over shapes, dtypes, and seeds (hypothesis itself is
not installed in this image, so the sweep is an explicit parameter grid +
seeded random data — same coverage, deterministic).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import rka_step_ref, rkab_block_ref, rkab_round_ref
from compile.kernels.rka_step import rka_step, vmem_estimate_bytes as rka_vmem
from compile.kernels.rkab_block import rkab_block, vmem_estimate_bytes as rkab_vmem
from compile.model import rka_step_model, rkab_block_model, rkab_round_model

SEEDS = [0, 1, 2]
VMEM_BUDGET = 16 * 1024 * 1024  # 16 MB VMEM per TPU core


def make_case(rng, q, bs, n, dtype):
    a = jnp.asarray(rng.normal(size=(q, bs, n)), dtype=dtype)
    b = jnp.asarray(rng.normal(size=(q, bs)), dtype=dtype)
    inv_norms = (1.0 / (a.astype(jnp.float64) ** 2).sum(-1)).astype(dtype)
    x = jnp.asarray(rng.normal(size=n), dtype=dtype)
    alpha = jnp.asarray([1.0], dtype=dtype)
    return a, b, inv_norms, x, alpha


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("q,n", [(1, 8), (2, 16), (4, 64), (8, 128), (16, 32)])
def test_rka_step_matches_ref(seed, q, n):
    rng = np.random.default_rng(seed)
    a, b, w, x, alpha = make_case(rng, q, 1, n, jnp.float64)
    got = rka_step(a[:, 0, :], b[:, 0], w[:, 0], x, alpha)
    want = rka_step_ref(a[:, 0, :], b[:, 0], w[:, 0], x, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("bs,n", [(1, 8), (4, 16), (32, 64), (128, 32), (64, 256)])
def test_rkab_block_matches_ref(seed, bs, n):
    rng = np.random.default_rng(10 + seed)
    a, b, w, x, alpha = make_case(rng, 1, bs, n, jnp.float64)
    got = rkab_block(a[0], b[0], w[0], x, alpha)
    want = rkab_block_ref(a[0], b[0], w[0], x, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-4), (jnp.float64, 1e-10)])
def test_rkab_block_dtypes(dtype, rtol):
    rng = np.random.default_rng(5)
    a, b, w, x, alpha = make_case(rng, 1, 16, 32, dtype)
    got = rkab_block(a[0], b[0], w[0], x, alpha)
    want = rkab_block_ref(
        a[0].astype(jnp.float64),
        b[0].astype(jnp.float64),
        w[0].astype(jnp.float64),
        x.astype(jnp.float64),
        alpha.astype(jnp.float64),
    )
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, dtype=np.float64), want, rtol=rtol, atol=rtol)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("q,bs,n", [(2, 4, 16), (4, 16, 32), (3, 8, 24)])
def test_rkab_round_model_matches_ref(seed, q, bs, n):
    rng = np.random.default_rng(20 + seed)
    a, b, w, x, alpha = make_case(rng, q, bs, n, jnp.float64)
    (got,) = rkab_round_model(a, b, w, x, alpha)
    want = rkab_round_ref(a, b, w, x, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_alpha_scaling_linearity():
    # rka_step is affine in alpha: step(2a) - x == 2*(step(a) - x).
    rng = np.random.default_rng(3)
    a, b, w, x, _ = make_case(rng, 4, 1, 32, jnp.float64)
    a2 = a[:, 0, :]
    s1 = rka_step(a2, b[:, 0], w[:, 0], x, jnp.asarray([1.0]))
    s2 = rka_step(a2, b[:, 0], w[:, 0], x, jnp.asarray([2.0]))
    np.testing.assert_allclose(s2 - x, 2.0 * (s1 - x), rtol=1e-12)


def test_block_sweep_reaches_hyperplanes():
    # After sweeping row j with alpha=1, row j's equation holds exactly at
    # that point of the sweep; for an orthogonal block the final v satisfies
    # *all* equations.
    n = 8
    a = jnp.eye(n, dtype=jnp.float64)
    x_true = jnp.arange(1.0, n + 1)
    b = a @ x_true
    w = jnp.ones(n, dtype=jnp.float64)
    v = rkab_block(a, b, w, jnp.zeros(n, dtype=jnp.float64), jnp.asarray([1.0]))
    np.testing.assert_allclose(v, x_true, rtol=1e-12)


def test_rkab_round_is_mean_of_blocks():
    rng = np.random.default_rng(7)
    q, bs, n = 3, 8, 16
    a, b, w, x, alpha = make_case(rng, q, bs, n, jnp.float64)
    (round_out,) = rkab_round_model(a, b, w, x, alpha)
    blocks = jnp.stack([rkab_block(a[t], b[t], w[t], x, alpha) for t in range(q)])
    np.testing.assert_allclose(round_out, blocks.mean(0), rtol=1e-12)


def test_convergence_property_random_system():
    # Iterating the round model on a consistent system converges to x_true.
    rng = np.random.default_rng(11)
    m, n, q, bs = 400, 16, 4, 16
    A = jnp.asarray(rng.normal(size=(m, n)))
    x_true = jnp.asarray(rng.normal(size=n))
    b_full = A @ x_true
    inv_norms_full = 1.0 / (A**2).sum(-1)
    x = jnp.zeros(n)
    alpha = jnp.asarray([1.0])
    for k in range(60):
        rows = rng.integers(0, m, size=(q, bs))
        (x,) = rkab_round_model(A[rows], b_full[rows], inv_norms_full[rows], x, alpha)
    err = float(jnp.linalg.norm(x - x_true) / jnp.linalg.norm(x_true))
    assert err < 1e-6, err


def test_vmem_estimates_within_budget():
    # Every AOT-exported shape must fit the TPU VMEM budget (DESIGN §Perf).
    from compile.aot import RKA_STEP_SHAPES, RKAB_BLOCK_SHAPES, RKAB_ROUND_SHAPES

    for q, n in RKA_STEP_SHAPES:
        assert rka_vmem(q, n) < VMEM_BUDGET
    for bs, n in RKAB_BLOCK_SHAPES:
        assert rkab_vmem(bs, n) < VMEM_BUDGET
    for q, bs, n in RKAB_ROUND_SHAPES:
        # vmapped kernel: one block instance per program.
        assert rkab_vmem(bs, n) < VMEM_BUDGET
