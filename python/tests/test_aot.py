"""AOT export contract tests: HLO text shape, manifest consistency, and
round-trip executability on the CPU PJRT client (the same client class the
Rust runtime wraps)."""

import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.ref import rkab_round_ref


def test_lower_all_covers_catalogue():
    items = list(aot.lower_all())
    expect = (
        len(aot.RKA_STEP_SHAPES) + len(aot.RKAB_BLOCK_SHAPES) + len(aot.RKAB_ROUND_SHAPES)
    )
    assert len(items) == expect
    names = [it[0] for it in items]
    assert len(set(names)) == len(names), "artifact names must be unique"


def test_hlo_text_is_parseable_entry():
    # Take one lowered artifact and sanity-check the HLO text contract:
    # an ENTRY computation returning a tuple (return_tuple=True).
    name, kind, q, bs, n, text = next(aot.lower_all())
    assert "ENTRY" in text
    assert "f64" in text, "artifacts must be double precision"
    assert text.count("parameter(") >= 5, "expected 5 parameters"


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out)],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    items = list(aot.lower_all())
    assert len(manifest) == len(items)
    for line in manifest:
        parts = line.split()
        assert len(parts) == 6
        assert (out / parts[5]).exists()


@pytest.mark.parametrize("q,bs,n", aot.RKAB_ROUND_SHAPES[:2])
def test_exported_round_matches_ref_numerically(q, bs, n):
    # Execute the lowered HLO via the jax CPU client (the same XLA codepath
    # the rust PjRtClient::cpu() uses) and compare against the oracle.
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(q, bs, n)))
    b = jnp.asarray(rng.normal(size=(q, bs)))
    w = 1.0 / (a**2).sum(-1)
    x = jnp.asarray(rng.normal(size=n))
    alpha = jnp.asarray([1.0])

    from compile.model import rkab_round_model

    compiled = jax.jit(rkab_round_model).lower(a, b, w, x, alpha).compile()
    (got,) = compiled(a, b, w, x, alpha)
    want = rkab_round_ref(a, b, w, x, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)
