//! Repo-local automation, invoked as `cargo xtask <command>` (the alias
//! lives in `.cargo/config.toml`).
//!
//! # `audit-unsafe`
//!
//! The unsafe-audit lint for `rust/src`. It fails the build when:
//!
//! - any `unsafe` block, fn, impl, or trait lacks an adjacent
//!   justification — a `// SAFETY:` comment on the same line or directly
//!   above (attributes and multi-line statement heads may intervene), or a
//!   `# Safety` doc section for `unsafe fn` declarations;
//! - the per-file `unsafe` occurrence counts drift from the committed
//!   budget in `unsafe_budget.toml` (growth *and* shrinkage: the budget is
//!   a ratchet, and a stale entry is as suspicious as a new site) — bump
//!   deliberately with `cargo xtask audit-unsafe --write-budget` after
//!   review;
//! - a disallowed pattern appears: `static mut` (always), `transmute`
//!   outside [`TRANSMUTE_ALLOWED`], or `Ordering::Relaxed` outside the
//!   audited [`RELAXED_ALLOWED`] files (each of which documents why
//!   relaxed suffices; their counts are also pinned by the budget);
//! - the crate root stops declaring `#![deny(unsafe_op_in_unsafe_fn)]`.
//!
//! The scanner is a lint, not a parser: it splits each line into code and
//! comment halves with a small string/char-literal-aware state machine
//! (block comments nest; string literals may span lines). Raw string
//! literals are not modeled — `rust/src` has none, and one containing
//! `unsafe` would at worst make the lint stricter, never blinder.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files (relative to `rust/src`) allowed to mention `transmute`.
/// `parallel/pool.rs` performs the audited lifetime erasure of the
/// dispatch job pointer — the protocol loom model-checks.
const TRANSMUTE_ALLOWED: &[&str] = &["parallel/pool.rs"];

/// Files (relative to `rust/src`) allowed to use `Ordering::Relaxed`.
/// Each use is justified in the source:
///
/// - `parallel/shared.rs` — `AtomicF64Vec` payload entries (independent
///   numeric values; cross-phase visibility comes from pool/barrier sync);
/// - `parallel/asyrk.rs` — the `ShutdownSignal::updates` telemetry counter
///   (exactness is ordered by the `live` Release/Acquire pair);
/// - `linalg/gemv.rs` — the tuned-panel cache (idempotent hint value);
/// - `batch/mod.rs` — the work-stealing ticket counter (fetch_add is the
///   only operation; no other memory rides on it);
/// - `metrics/progress.rs` — test-only counters behind a channel.
const RELAXED_ALLOWED: &[&str] = &[
    "batch/mod.rs",
    "linalg/gemv.rs",
    "metrics/progress.rs",
    "parallel/asyrk.rs",
    "parallel/shared.rs",
];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("audit-unsafe") => {
            let write = args.iter().any(|a| a == "--write-budget");
            audit_unsafe(write)
        }
        _ => {
            eprintln!("usage: cargo xtask audit-unsafe [--write-budget]");
            ExitCode::FAILURE
        }
    }
}

/// A source line split into its code and comment halves.
struct Line {
    code: String,
    comment: String,
}

impl Line {
    fn is_pure_comment(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }
}

/// Split `src` into per-line code/comment halves. Line (`//`) and nesting
/// block (`/* */`) comments go to `comment`; string and char literals are
/// blanked out of `code` (so their contents can never look like keywords);
/// everything else stays in `code`.
fn strip_lines(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut block_depth = 0usize;
    let mut in_string = false;
    for raw in src.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::new();
        let mut comment = String::new();
        let mut i = 0;
        while i < chars.len() {
            if block_depth > 0 {
                if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    i += 2;
                } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    i += 2;
                } else {
                    comment.push(chars[i]);
                    i += 1;
                }
                continue;
            }
            if in_string {
                if chars[i] == '\\' {
                    i += 2;
                } else {
                    if chars[i] == '"' {
                        in_string = false;
                    }
                    i += 1;
                }
                continue;
            }
            match chars[i] {
                '/' if chars.get(i + 1) == Some(&'/') => {
                    comment.extend(&chars[i..]);
                    i = chars.len();
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    block_depth += 1;
                    i += 2;
                }
                '"' => {
                    code.push(' ');
                    in_string = true;
                    i += 1;
                }
                '\'' => {
                    // Char literal vs lifetime: a literal closes within a
                    // short escape-aware window; a lifetime never closes.
                    match char_literal_end(&chars, i) {
                        Some(end) => {
                            code.push(' ');
                            i = end;
                        }
                        None => {
                            code.push('\'');
                            i += 1;
                        }
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(Line { code, comment });
    }
    out
}

/// If `chars[start]` (a `'`) opens a char literal, return the index one
/// past its closing quote; `None` for lifetimes.
fn char_literal_end(chars: &[char], start: usize) -> Option<usize> {
    let mut j = start + 1;
    if chars.get(j) == Some(&'\\') {
        j += 1;
        if chars.get(j) == Some(&'u') && chars.get(j + 1) == Some(&'{') {
            j += 2;
            while chars.get(j).is_some_and(|&c| c != '}') {
                j += 1;
            }
        }
        j += 1; // the escaped character (or the closing `}`)
    } else if chars.get(j).is_some_and(|&c| c != '\'') {
        j += 1;
    } else {
        return None; // `''` — not a literal
    }
    (chars.get(j) == Some(&'\'')).then_some(j + 1)
}

/// Byte offsets of standalone-word occurrences of `word` in `hay`.
fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut found = Vec::new();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(word) {
        let at = from + pos;
        let before_ok = !hay[..at].chars().next_back().is_some_and(is_ident);
        let after_ok = !hay[at + word.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            found.push(at);
        }
        from = at + word.len();
    }
    found
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum UnsafeKind {
    Block,
    Fn,
    Impl,
    Trait,
}

struct UnsafeSite {
    /// 0-based line index of the `unsafe` keyword.
    line: usize,
    kind: UnsafeKind,
}

/// Locate every `unsafe` keyword in the stripped code and classify what it
/// introduces (the next code token, possibly on a following line).
fn find_unsafe_sites(lines: &[Line]) -> Vec<UnsafeSite> {
    let mut sites = Vec::new();
    for (ln, line) in lines.iter().enumerate() {
        for at in find_word(&line.code, "unsafe") {
            let mut rest = line.code[at + "unsafe".len()..].trim_start().to_string();
            let mut look = ln + 1;
            while rest.is_empty() && look < lines.len() {
                rest = lines[look].code.trim_start().to_string();
                look += 1;
            }
            let kind = if rest.starts_with('{') {
                UnsafeKind::Block
            } else if rest.starts_with("fn") {
                UnsafeKind::Fn
            } else if rest.starts_with("impl") {
                UnsafeKind::Impl
            } else if rest.starts_with("trait") {
                UnsafeKind::Trait
            } else {
                // `unsafe` in some position the classifier does not know
                // (e.g. `unsafe extern`); treat as a block so it still
                // demands a SAFETY comment.
                UnsafeKind::Block
            };
            sites.push(UnsafeSite { line: ln, kind });
        }
    }
    sites
}

/// Does `site` carry an adjacent justification? Accepted forms:
///
/// - `// SAFETY:` trailing on the same line;
/// - a contiguous `// SAFETY:` comment block directly above (the statement
///   head of a multi-line expression and attribute lines may sit between);
/// - for `unsafe fn`/`impl`/`trait`: a doc block containing `# Safety`.
fn has_safety_justification(lines: &[Line], site: &UnsafeSite) -> bool {
    if lines[site.line].comment.contains("SAFETY:") {
        return true;
    }
    let mut i = site.line;
    while i > 0 {
        i -= 1;
        let l = &lines[i];
        let code = l.code.trim();
        if code.is_empty() {
            if !l.comment.trim().is_empty() {
                return comment_block_has_safety(lines, i, site.kind);
            }
            return false; // blank line breaks adjacency
        }
        if code.starts_with("#[") || code.starts_with("#!") {
            continue; // attributes may sit between the doc block and item
        }
        // A line ending a previous statement/item stops the walk; anything
        // else is the head of the same multi-line expression (`let x =`).
        if code.ends_with(';') || code.ends_with('{') || code.ends_with('}') {
            return false;
        }
    }
    false
}

/// Scan the contiguous pure-comment run ending at line `i` for a
/// justification marker.
fn comment_block_has_safety(lines: &[Line], mut i: usize, kind: UnsafeKind) -> bool {
    loop {
        let c = &lines[i].comment;
        if c.contains("SAFETY:") {
            return true;
        }
        if kind != UnsafeKind::Block && c.contains("# Safety") {
            return true;
        }
        if i == 0 || !lines[i - 1].is_pure_comment() {
            return false;
        }
        i -= 1;
    }
}

/// Per-file scan results.
#[derive(Default)]
struct FileAudit {
    unsafe_count: usize,
    relaxed_count: usize,
    transmute_count: usize,
    /// 1-based lines of unsafe sites lacking a justification.
    undocumented: Vec<usize>,
    /// 1-based lines containing `static mut`.
    static_mut: Vec<usize>,
}

fn audit_file(src: &str) -> FileAudit {
    let lines = strip_lines(src);
    let sites = find_unsafe_sites(&lines);
    let mut audit = FileAudit { unsafe_count: sites.len(), ..FileAudit::default() };
    for site in &sites {
        if !has_safety_justification(&lines, site) {
            audit.undocumented.push(site.line + 1);
        }
    }
    for (ln, line) in lines.iter().enumerate() {
        audit.relaxed_count += line.code.matches("Ordering::Relaxed").count();
        audit.transmute_count += find_word(&line.code, "transmute").len();
        for at in find_word(&line.code, "static") {
            if line.code[at + "static".len()..].trim_start().starts_with("mut ") {
                audit.static_mut.push(ln + 1);
            }
        }
    }
    audit
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rust_files(dir: &Path) -> Vec<PathBuf> {
    let mut files = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = fs::read_dir(&d).unwrap_or_else(|e| panic!("read {}: {e}", d.display()));
        for entry in entries {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x.to_str() == Some("rs")) {
                files.push(path);
            }
        }
    }
    files.sort();
    files
}

/// Parse the budget file's TOML subset: `[section]` headers and
/// `"key" = integer` entries (comments and blank lines ignored).
fn parse_budget(src: &str) -> BTreeMap<String, BTreeMap<String, usize>> {
    let mut sections = BTreeMap::new();
    let mut current = String::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            current = name.to_string();
            sections.entry(current.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .unwrap_or_else(|| panic!("unsafe_budget.toml line {}: not key = value", ln + 1));
        let key = key.trim().trim_matches('"').to_string();
        let value: usize = value
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("unsafe_budget.toml line {}: {e}", ln + 1));
        sections.entry(current.clone()).or_default().insert(key, value);
    }
    sections
}

fn render_budget(
    unsafe_counts: &BTreeMap<String, usize>,
    relaxed: &BTreeMap<String, usize>,
    transmute: &BTreeMap<String, usize>,
) -> String {
    let mut out = String::from(
        "# Per-file budget for `unsafe` and related audited patterns in rust/src.\n\
         #\n\
         # Checked exactly (growth AND shrinkage) by `cargo xtask audit-unsafe`\n\
         # in CI: adding an unsafe site without bumping its budget here fails\n\
         # the lint, which forces the diff that grows the unsafe surface to\n\
         # also touch this file — where a reviewer sees it. Regenerate after\n\
         # review with `cargo xtask audit-unsafe --write-budget`.\n\
         #\n\
         # Keys are paths relative to rust/src; counts are keyword\n\
         # occurrences in code (comments, docs, and strings excluded).\n",
    );
    let sections = [("unsafe", unsafe_counts), ("relaxed", relaxed), ("transmute", transmute)];
    for (section, counts) in sections {
        let _ = write!(out, "\n[{section}]\n");
        for (file, count) in counts {
            let _ = writeln!(out, "\"{file}\" = {count}");
        }
    }
    out
}

fn audit_unsafe(write_budget: bool) -> ExitCode {
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("workspace root");
    let src_root = repo_root.join("rust").join("src");
    let budget_path = repo_root.join("unsafe_budget.toml");

    let mut violations: Vec<String> = Vec::new();
    let mut unsafe_counts = BTreeMap::new();
    let mut relaxed_counts = BTreeMap::new();
    let mut transmute_counts = BTreeMap::new();

    for path in rust_files(&src_root) {
        let rel = path
            .strip_prefix(&src_root)
            .expect("under src root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let audit = audit_file(&src);

        for line in &audit.undocumented {
            violations.push(format!(
                "{rel}:{line}: unsafe without an adjacent `// SAFETY:` comment \
                 (or `# Safety` doc section for unsafe fns)"
            ));
        }
        for line in &audit.static_mut {
            violations.push(format!("{rel}:{line}: `static mut` is banned (use atomics)"));
        }
        if audit.transmute_count > 0 && !TRANSMUTE_ALLOWED.contains(&rel.as_str()) {
            violations.push(format!(
                "{rel}: `transmute` outside the audited allowlist ({TRANSMUTE_ALLOWED:?})"
            ));
        }
        if audit.relaxed_count > 0 && !RELAXED_ALLOWED.contains(&rel.as_str()) {
            violations.push(format!(
                "{rel}: `Ordering::Relaxed` outside the audited allowlist \
                 ({RELAXED_ALLOWED:?}); use Acquire/Release or get the file audited"
            ));
        }
        if audit.unsafe_count > 0 {
            unsafe_counts.insert(rel.clone(), audit.unsafe_count);
        }
        if audit.relaxed_count > 0 {
            relaxed_counts.insert(rel.clone(), audit.relaxed_count);
        }
        if audit.transmute_count > 0 {
            transmute_counts.insert(rel.clone(), audit.transmute_count);
        }
    }

    // The lint that keeps every future unsafe operation inside an explicit,
    // commentable block must stay in the crate root.
    let lib_rs = fs::read_to_string(src_root.join("lib.rs")).expect("read rust/src/lib.rs");
    if !lib_rs.contains("#![deny(unsafe_op_in_unsafe_fn)]") {
        let msg = "lib.rs: missing `#![deny(unsafe_op_in_unsafe_fn)]` in the crate root";
        violations.push(msg.to_string());
    }

    if write_budget {
        let rendered = render_budget(&unsafe_counts, &relaxed_counts, &transmute_counts);
        fs::write(&budget_path, rendered).expect("write unsafe_budget.toml");
        println!("wrote {}", budget_path.display());
    } else {
        match fs::read_to_string(&budget_path) {
            Err(_) => {
                let msg = "unsafe_budget.toml missing at the repository root; generate it \
                           with `cargo xtask audit-unsafe --write-budget`";
                violations.push(msg.to_string());
            }
            Ok(src) => {
                let budget = parse_budget(&src);
                let empty = BTreeMap::new();
                let sections = [
                    ("unsafe", &unsafe_counts),
                    ("relaxed", &relaxed_counts),
                    ("transmute", &transmute_counts),
                ];
                for (section, actual) in sections {
                    let budgeted = budget.get(section).unwrap_or(&empty);
                    for (file, &count) in actual {
                        match budgeted.get(file) {
                            Some(&b) if b == count => {}
                            Some(&b) => violations.push(format!(
                                "{file}: [{section}] count {count} != budget {b}; review the \
                                 change, then `cargo xtask audit-unsafe --write-budget`"
                            )),
                            None => violations.push(format!(
                                "{file}: {count} [{section}] site(s) but no budget entry; \
                                 review, then `cargo xtask audit-unsafe --write-budget`"
                            )),
                        }
                    }
                    for file in budgeted.keys() {
                        if !actual.contains_key(file) {
                            violations.push(format!(
                                "{file}: stale [{section}] budget entry (file now clean); \
                                 regenerate with `cargo xtask audit-unsafe --write-budget`"
                            ));
                        }
                    }
                }
            }
        }
    }

    if violations.is_empty() {
        let sites: usize = unsafe_counts.values().sum();
        println!(
            "audit-unsafe: OK — {sites} documented unsafe site(s) across {} file(s), \
             budget in sync",
            unsafe_counts.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("audit-unsafe: {} violation(s):", violations.len());
        for v in &violations {
            eprintln!("  {v}");
        }
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_strings_and_char_literals() {
        let lines = strip_lines(
            "let a = \"unsafe in a string\"; // unsafe in a comment\n\
             let c = 'u'; let l: &'static str = \"x\";\n\
             /* unsafe in a block\n\
             comment */ let b = 2;",
        );
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].comment.contains("unsafe in a comment"));
        assert!(lines[1].code.contains("&'static str"));
        assert!(!lines[1].code.contains('u'));
        assert!(lines[2].comment.contains("unsafe in a block"));
        assert!(lines[3].code.contains("let b = 2"));
    }

    #[test]
    fn multiline_strings_stay_stripped() {
        let lines = strip_lines("let m = \"first \\\n unsafe second\";\nlet x = 1;");
        assert!(!lines[1].code.contains("unsafe"));
        assert!(lines[2].code.contains("let x = 1"));
    }

    #[test]
    fn undocumented_block_is_flagged() {
        let audit = audit_file("fn f() {\n    let x = unsafe { g() };\n}\n");
        assert_eq!(audit.unsafe_count, 1);
        assert_eq!(audit.undocumented, vec![2]);
    }

    #[test]
    fn same_line_and_preceding_safety_comments_pass() {
        let audit = audit_file(
            "fn f() {\n\
             \x20   let x = unsafe { g() }; // SAFETY: g is sound here\n\
             \x20   // SAFETY: h is sound here\n\
             \x20   // because reasons.\n\
             \x20   let y = unsafe { h() };\n\
             }\n",
        );
        assert_eq!(audit.unsafe_count, 2);
        assert!(audit.undocumented.is_empty());
    }

    #[test]
    fn multiline_statement_head_does_not_break_adjacency() {
        let audit = audit_file(
            "// SAFETY: disjoint ranges.\n\
             let mine =\n\
             \x20   unsafe { s.range_mut_unchecked(lo, hi) };\n",
        );
        assert_eq!(audit.unsafe_count, 1);
        assert!(audit.undocumented.is_empty());
    }

    #[test]
    fn safety_doc_section_covers_unsafe_fn_but_not_blocks() {
        let covered = audit_file(
            "/// Does a thing.\n\
             ///\n\
             /// # Safety\n\
             /// Caller promises everything.\n\
             #[inline]\n\
             pub unsafe fn f() {}\n",
        );
        assert_eq!(covered.unsafe_count, 1);
        assert!(covered.undocumented.is_empty());
        // A `# Safety` doc on a *block* is a doc bug, not a justification.
        let block = audit_file("/// # Safety\nlet x = unsafe { g() };\n");
        assert_eq!(block.undocumented, vec![2]);
    }

    #[test]
    fn blank_line_breaks_adjacency() {
        let audit = audit_file("// SAFETY: stale comment.\n\nlet x = unsafe { g() };\n");
        assert_eq!(audit.undocumented, vec![3]);
    }

    #[test]
    fn word_boundaries_respected() {
        let audit = audit_file("fn unsafety() {}\nlet transmuted = 1;\n");
        assert_eq!(audit.unsafe_count, 0);
        assert_eq!(audit.transmute_count, 0);
    }

    #[test]
    fn relaxed_counted_in_code_not_docs() {
        let audit = audit_file(
            "/// Uses Ordering::Relaxed in the doc only.\n\
             let a = x.load(Ordering::Relaxed);\n\
             let b = c.compare_exchange(a, a, Ordering::Relaxed, Ordering::Relaxed);\n",
        );
        assert_eq!(audit.relaxed_count, 3);
    }

    #[test]
    fn static_mut_and_transmute_detected() {
        let audit = audit_file("static mut GLOBAL: u32 = 0;\nlet y = std::mem::transmute(x);\n");
        assert_eq!(audit.static_mut, vec![1]);
        assert_eq!(audit.transmute_count, 1);
    }

    #[test]
    fn unsafe_impl_classified_and_requires_comment() {
        let src = "unsafe impl Send for T {}\n";
        let lines = strip_lines(src);
        assert_eq!(find_unsafe_sites(&lines)[0].kind, UnsafeKind::Impl);
        assert_eq!(audit_file(src).undocumented, vec![1]);
        let ok = audit_file("// SAFETY: T owns its data.\nunsafe impl Send for T {}\n");
        assert!(ok.undocumented.is_empty());
    }

    #[test]
    fn budget_roundtrip() {
        let mut unsafe_counts = BTreeMap::new();
        unsafe_counts.insert("parallel/shared.rs".to_string(), 10);
        let relaxed = BTreeMap::new();
        let transmute = BTreeMap::new();
        let rendered = render_budget(&unsafe_counts, &relaxed, &transmute);
        let parsed = parse_budget(&rendered);
        assert_eq!(parsed["unsafe"]["parallel/shared.rs"], 10);
        assert!(parsed["relaxed"].is_empty());
    }
}
